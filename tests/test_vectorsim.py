"""Equivalence suite: vectorized pre-decoded engine vs scalar interpreter.

The perf-mode simulator's vectorized engine (:mod:`repro.core.vectorsim`)
must be *bit-identical* to the scalar interpreter — same cycles, same
stage makespans, same energy-event ledger, same per-unit busy totals,
same executed-instruction count (including blocked-RECV retries).  This
suite pins that contract on the golden compiled workloads and on
hypothesis-randomized programs covering the decodable subset
(communication rendezvous, barriers, gmem port contention, blocked
receives), plus the fallback semantics for programs outside it.
"""

import numpy as np
import pytest

from repro import flow
from repro.core import vectorsim
from repro.core.arch import default_chip
from repro.core.codegen import StageProgram, _ensure_vec_flag_operand
from repro.core.isa import Instr, Program, SREG, default_isa
from repro.core.mapping import CostParams
from repro.core.simulator import Deadlock, SimError, Simulator

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.filterwarnings(
    "ignore:perf-mode lmem overflow:RuntimeWarning")

CHIP = default_chip()
ISA = default_isa()
_ensure_vec_flag_operand(ISA)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def run_stage_both(programs):
    """(makespan, events, busy, instrs) from both engines on one stage."""
    sp = StageProgram(stage=None, schedules=[], programs=programs)
    scal = Simulator(CHIP, ISA, engine="scalar")
    out_s = scal._run_stage(sp, None)
    vec = Simulator(CHIP, ISA, engine="vector")
    out_v = vectorsim.run_stage(vec, sp)
    assert out_v is not None, "stage unexpectedly not decodable"
    return out_s, out_v


def assert_identical(out_s, out_v):
    makespan_s, events_s, busy_s, instrs_s = out_s
    makespan_v, events_v, busy_v, instrs_v = out_v
    assert makespan_v == makespan_s
    assert events_v == events_s
    assert busy_v == busy_s
    assert instrs_v == instrs_s


def assert_reports_identical(a, b):
    assert a.cycles == b.cycles
    assert a.stage_cycles == b.stage_cycles
    assert a.events == b.events
    assert a.unit_busy == b.unit_busy
    assert a.instrs == b.instrs


def prog(core_id, *instrs):
    p = Program(core_id=core_id)
    for op, args in instrs:
        p.append(ISA.instr(op, **args))
    return p


def I(op, **args):                       # noqa: E743 — terse test DSL
    return (op, args)


# ---------------------------------------------------------------------------
# golden compiled workloads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model,kw,strategy", [
    ("tiny_cnn", {}, "dp"),
    ("tiny_cnn", {}, "generic"),
    ("resnet18", {"res": 64}, "dp"),
    # dynamic-weight attention: per-sample mid-stage CIM writes must
    # replay bit-identically (weight gather V_MOVs + CIM_LOAD from the
    # RECV'd activations are core-local block ops)
    ("transformer", {"n_layers": 1, "d_model": 128, "n_heads": 4,
                     "seq": 16, "vocab": 64}, "dp"),
])
def test_golden_workload_equivalence(model, kw, strategy):
    art = flow.compile(model, CHIP,
                       flow.CompileOptions(strategy=strategy,
                                           params=CostParams(batch=2),
                                           workload_kw=kw or None))
    cm = art.ensure_model()
    scal = Simulator(CHIP, cm.isa, engine="scalar").run_model(cm)
    vec = Simulator(CHIP, cm.isa, engine="vector").run_model(cm)
    assert_reports_identical(scal, vec)


def test_golden_vector_engine_actually_used():
    """engine='vector' must not silently fall back on compiled code."""
    art = flow.compile("tiny_cnn", CHIP,
                       flow.CompileOptions(params=CostParams(batch=2)))
    cm = art.ensure_model()
    rep = Simulator(CHIP, cm.isa, engine="vector").run_model(cm)
    assert rep.cycles > 0


# ---------------------------------------------------------------------------
# hand-built corner cases
# ---------------------------------------------------------------------------


def _send(core, dst, size, stream, value_reg_base=1):
    r = value_reg_base
    return [
        I("CIM_CFG", sreg=SREG["CHANNEL"], imm=stream),
        I("S_ADDI", dst=r, a=0, imm=dst),
        I("S_ADDI", dst=r + 1, a=0, imm=64),
        I("S_ADDI", dst=r + 2, a=0, imm=size),
        I("SEND", core=r, src=r + 1, size=r + 2),
    ]


def _recv(core, src, size, stream, value_reg_base=4):
    r = value_reg_base
    return [
        I("CIM_CFG", sreg=SREG["CHANNEL"], imm=stream),
        I("S_ADDI", dst=r, a=0, imm=128),
        I("S_ADDI", dst=r + 1, a=0, imm=src),
        I("S_ADDI", dst=r + 2, a=0, imm=size),
        I("RECV", dst=r, core=r + 1, size=r + 2),
    ]


def test_recv_blocks_until_send():
    # receiver is scheduled first, blocks, retries — retry attempts
    # count as executed instructions in both engines
    p0 = prog(0, *(_send(0, 1, 32, 7)
                   + [I("S_ADDI", dst=5, a=0, imm=1)] * 50
                   + [I("HALT", )]))
    p1 = prog(1, *(_recv(1, 0, 32, 7) + [I("HALT",)]))
    assert_identical(*run_stage_both({0: p0, 1: p1}))


def test_recv_size_mismatch_raises_same():
    p0 = prog(0, *(_send(0, 1, 32, 3) + [I("HALT",)]))
    p1 = prog(1, *(_recv(1, 0, 16, 3) + [I("HALT",)]))
    sp = StageProgram(stage=None, schedules=[], programs={0: p0, 1: p1})
    with pytest.raises(SimError, match="size mismatch"):
        Simulator(CHIP, ISA, engine="scalar")._run_stage(sp, None)
    with pytest.raises(SimError, match="size mismatch"):
        vectorsim.run_stage(Simulator(CHIP, ISA, engine="vector"), sp)


def test_deadlock_raises_same():
    p0 = prog(0, *(_recv(0, 1, 8, 1) + [I("HALT",)]))
    p1 = prog(1, I("HALT",))
    sp = StageProgram(stage=None, schedules=[], programs={0: p0, 1: p1})
    with pytest.raises(Deadlock):
        Simulator(CHIP, ISA, engine="scalar")._run_stage(sp, None)
    with pytest.raises(Deadlock):
        vectorsim.run_stage(Simulator(CHIP, ISA, engine="vector"), sp)


def test_sync_barrier_and_gmem_ports():
    def core_prog(cid, delay):
        body = [I("S_ADDI", dst=1, a=0, imm=256),
                I("S_ADDI", dst=2, a=0, imm=1024 * cid),
                I("S_ADDI", dst=3, a=0, imm=200 + delay)]
        body += [I("NOP",)] * delay
        body += [I("GLD", dst=1, gaddr=2, size=3)]
        body += [I("SYNC", barrier=1)]
        body += [I("GST", src=1, gaddr=2, size=3)]
        body += [I("HALT",)]
        return prog(cid, *body)

    programs = {c: core_prog(c, 3 * c) for c in range(5)}
    assert_identical(*run_stage_both(programs))


def test_cfgr_and_lui_addi_chains():
    # big S_Reg value through the G_Reg path (CIM_CFGR), LUI/ADDI pairs
    p = prog(0,
             I("S_LUI", dst=9, imm=2),              # 0x20000
             I("S_ADDI", dst=9, a=9, imm=100),
             I("CIM_CFGR", sreg=SREG["VLEN"], src=9),
             I("V_ADD", dst=1, a=2, b=3),           # vlen = 131172
             I("S_LD", dst=9, base=1, off=0),       # perf: no writeback
             I("CIM_CFGR", sreg=SREG["VLEN"], src=9),
             I("V_ADD", dst=1, a=2, b=3),           # vlen unchanged
             I("HALT",))
    assert_identical(*run_stage_both({0: p}))


def test_mvm_occupancy_and_vector_classes():
    p = prog(0,
             I("CIM_CFG", sreg=SREG["MG_NLEN"], imm=16),
             I("CIM_CFG", sreg=SREG["MG_KOFF"], imm=0),
             I("S_ADDI", dst=1, a=0, imm=0),
             I("CIM_LOAD", mg=0, src=1, rows=64),
             I("CIM_LOAD", mg=2, src=1, rows=32),
             I("CIM_CFG", sreg=SREG["MG_MASK_LO"], imm=0b101),
             I("CIM_CFG", sreg=SREG["MVM_SEG_IN"], imm=64),
             I("CIM_CFG", sreg=SREG["MVM_SEG_OUT"], imm=128),
             I("CIM_MVM", dst=1, src=1, rep=7, acc=0),
             I("V_SETVL", len=48),
             I("CIM_CFG", sreg=SREG["V_REP"], imm=3),
             I("V_MUL", dst=1, a=2, b=3),            # mul class
             I("V_SIGMOID", dst=1, a=2, b=0),        # special class
             I("V_MAX", dst=1, a=2, b=3, flags=4),   # alu class, i8
             I("HALT",))
    assert_identical(*run_stage_both({0: p}))


def test_dead_code_after_halt_is_ignored():
    # unsupported ops after HALT must not force the scalar fallback —
    # the interpreter never dispatches them either
    p = prog(0,
             I("S_ADDI", dst=1, a=0, imm=3),
             I("HALT",),
             I("S_ADD", dst=1, a=1, b=1),    # dead, outside the subset
             I("BEQ", a=0, b=0, off=-2))     # dead branch
    sp = StageProgram(stage=None, schedules=[], programs={0: p})
    out_v = vectorsim.run_stage(Simulator(CHIP, ISA, engine="vector"),
                                sp)
    assert out_v is not None
    out_s = Simulator(CHIP, ISA, engine="scalar")._run_stage(sp, None)
    assert_identical(out_s, out_v)


def test_branchy_program_unrolls_statically():
    # a live countdown loop is statically resolved at decode time (the
    # perf-mode register file never depends on simulated data): the
    # vector engine unrolls it and stays bit-identical, including the
    # per-iteration branch latencies and instruction counts
    body = [I("S_ADDI", dst=1, a=0, imm=3),
            I("S_ADDI", dst=2, a=0, imm=0),
            I("S_ADDI", dst=1, a=1, imm=-1),
            I("BNE", a=1, b=2, off=-1),
            I("HALT",)]
    p = prog(0, *body)
    assert_identical(*run_stage_both({0: p}))


def test_scalar_alu_chain_unrolls():
    # cross-register scalar ALU chains feeding a GLD size / a vector
    # length: resolved by the decode-time pre-execution
    body = [I("S_ADDI", dst=1, a=0, imm=6),
            I("S_ADDI", dst=2, a=0, imm=7),
            I("S_MUL", dst=3, a=1, b=2),        # 42
            I("S_ADD", dst=3, a=3, b=1),        # 48
            I("S_ADDI", dst=4, a=0, imm=256),
            I("GLD", dst=4, gaddr=4, size=3),
            I("CIM_CFGR", sreg=SREG["VLEN"], src=3),
            I("V_ADD", dst=1, a=2, b=3),
            I("HALT",)]
    p = prog(0, *body)
    assert_identical(*run_stage_both({0: p}))


def test_loop_with_comms_unrolls():
    # a loop body containing SEND/RECV rendezvous: the unrolled trace
    # must preserve boundary ordering and per-retry instruction counts
    sends = []
    recvs = []
    for it in range(3):
        sends += _send(0, 1, 16, 40 + it)
        recvs += _recv(1, 0, 16, 40 + it)
    p0 = prog(0, *(sends
                   + [I("S_ADDI", dst=9, a=0, imm=2),
                      I("S_ADDI", dst=9, a=9, imm=-1),
                      I("BNE", a=9, b=0, off=-1),
                      I("HALT",)]))
    p1 = prog(1, *(recvs + [I("HALT",)]))
    assert_identical(*run_stage_both({0: p0, 1: p1}))


def test_custom_op_falls_back_to_scalar():
    # instructions outside even the unrollable subset (custom
    # descriptors the simulator has no semantics for) still force the
    # per-stage fallback; engine="vector" must refuse
    from repro.core.isa import InstrDescriptor, default_isa as _disa
    isa2 = _disa()
    isa2.register(InstrDescriptor(name="X_CUSTOM", opcode=60, fmt="J",
                                  unit="scalar", operands={}))
    p = Program(core_id=0)
    p.append(isa2.instr("X_CUSTOM"))
    p.append(isa2.instr("HALT"))
    sp = StageProgram(stage=None, schedules=[], programs={0: p})
    assert vectorsim.run_stage(Simulator(CHIP, isa2, engine="vector"),
                               sp) is None

    class _M:                     # minimal CompiledModel stand-in
        stages = [sp]
        layout = None

    with pytest.raises(SimError, match="not statically decodable"):
        Simulator(CHIP, isa2, engine="vector").run_model(_M())


def test_auto_engine_fallback_equivalence(monkeypatch):
    # engine="auto" must fall back per stage and report identically to
    # the interpreter.  A tiny unroll cap forces the branchy program
    # out of the decodable subset without needing an op the scalar
    # interpreter cannot execute.
    monkeypatch.setattr(vectorsim.StageDecoder, "UNROLL_CAP", 4)
    body = [I("S_ADDI", dst=1, a=0, imm=5),
            I("S_ADDI", dst=2, a=0, imm=0),
            I("S_ADDI", dst=1, a=1, imm=-1),
            I("BNE", a=1, b=2, off=-1),
            I("HALT",)]
    p = prog(0, *body)
    sp = StageProgram(stage=None, schedules=[], programs={0: p})
    assert vectorsim.run_stage(Simulator(CHIP, ISA, engine="vector"),
                               sp) is None

    class _M:
        stages = [sp]
        layout = None

    rep_auto = Simulator(CHIP, ISA, engine="auto").run_model(_M())
    rep_scal = Simulator(CHIP, ISA, engine="scalar").run_model(_M())
    assert_reports_identical(rep_scal, rep_auto)


def test_engine_validation():
    with pytest.raises(ValueError):
        Simulator(CHIP, ISA, engine="warp")
    with pytest.raises(ValueError):
        Simulator(CHIP, ISA, mode="func", engine="vector")


def test_nonpow2_bandwidth_divisors_exact():
    """Block replay pre-sums run latencies — exact for dyadic latencies
    by construction.  A chip with non-power-of-two bandwidth divisors
    (1/3-cycle weight-load rows, 3-flit links, 48 B/cycle gmem ports)
    produces non-dyadic floats where re-association *could* differ in
    the last ulp; pin that the replay still matches the interpreter
    bit-exactly on a compiled workload (the run-collapse only ever adds
    the same addends in the same left-to-right order)."""
    import dataclasses
    base = default_chip(n_cores=8, mesh_cols=4)
    chip = dataclasses.replace(
        base,
        core=dataclasses.replace(
            base.core,
            cim=dataclasses.replace(base.core.cim,
                                    weight_load_rows_per_cycle=3)),
        noc=dataclasses.replace(base.noc, flits_per_cycle=3),
        global_mem_bytes_per_cycle=48,
        name="nonpow2-divisors")
    art = flow.compile("tiny_cnn", chip,
                       flow.CompileOptions(params=CostParams(batch=2)))
    cm = art.ensure_model()
    scal = Simulator(chip, cm.isa, engine="scalar").run_model(cm)
    vec = Simulator(chip, cm.isa, engine="vector").run_model(cm)
    # timing is bit-exact even for non-dyadic latencies: the replay's
    # run collapse adds the same addends in the interpreter's order
    assert vec.cycles == scal.cycles
    assert vec.stage_cycles == scal.stage_cycles
    assert vec.events == scal.events
    assert vec.instrs == scal.instrs
    # the busy *ledger* is a pure sum and may re-associate: bound it at
    # one ulp (documented exactness note from the PR-4 ROADMAP entry)
    for unit, b in scal.unit_busy.items():
        assert vec.unit_busy[unit] == pytest.approx(b, rel=1e-12)


def test_lazy_lmem_allocation():
    from repro.core.simulator import _Core
    perf = _Core(0, Program(core_id=0), CHIP, func=False)
    assert perf.lmem is None and perf._lmem is None
    func = _Core(0, Program(core_id=0), CHIP, func=True)
    assert func._lmem is None            # nothing allocated up front
    assert func.lmem is not None         # materializes on first touch
    assert func.lmem.nbytes == CHIP.core.local_mem.size_bytes


def test_packed_program_columns():
    p = prog(3, I("S_ADDI", dst=4, a=0, imm=-7),
             I("CIM_CFG", sreg=5, imm=9), I("HALT",))
    packed = p.pack(ISA)
    assert len(packed) == 3
    assert packed.core_id == 3
    assert packed.op.tolist() == [ISA.op_id("S_ADDI"),
                                  ISA.op_id("CIM_CFG"),
                                  ISA.op_id("HALT")]
    assert packed.col("imm").tolist() == [-7, 9, 0]
    assert packed.col("dst").tolist() == [4, 0, 0]
    assert p.pack(ISA) is packed         # memoized


# ---------------------------------------------------------------------------
# hypothesis: randomized decodable programs
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    _N_CORES = 3

    @st.composite
    def stage_programs(draw):
        """Random multi-core stage in the decodable subset.

        Construction guarantees liveness: within a phase every core
        emits its SENDs before its RECVs (SEND never blocks), message
        streams are unique per message (per-channel FIFO is trivially
        consistent), and phases end with an all-core SYNC.
        """
        rng_local = st.sampled_from([
            lambda d: [I("NOP",)],
            lambda d: [I("S_ADDI", dst=d.draw(st.integers(1, 5)), a=0,
                         imm=d.draw(st.integers(-100, 100)))],
            lambda d: [I("S_LUI", dst=d.draw(st.integers(1, 5)),
                         imm=d.draw(st.integers(0, 50)))],
            lambda d: [I("S_LD", dst=6, base=1, off=0)],
            lambda d: [I("S_ST", src=6, base=1, off=4)],
            lambda d: [I("V_SETVL", len=d.draw(st.integers(1, 200)))],
            lambda d: [I("CIM_CFG", sreg=SREG["V_REP"],
                         imm=d.draw(st.integers(0, 4)))],
            lambda d: [I("V_ADD", dst=1, a=2, b=3)],
            lambda d: [I("V_QUANT", dst=1, a=2, b=0,
                         flags=d.draw(st.sampled_from([0, 4])))],
            lambda d: [I("V_EXP", dst=1, a=2, b=0)],
            lambda d: [I("CIM_CFG", sreg=SREG["MG_NLEN"],
                         imm=d.draw(st.integers(1, 64)))],
            lambda d: [I("CIM_LOAD", mg=d.draw(st.integers(0, 3)),
                         src=1, rows=d.draw(st.integers(1, 128)))],
            lambda d: [I("CIM_CFG", sreg=SREG["MG_MASK_LO"],
                         imm=d.draw(st.integers(0, 15)))],
            lambda d: [I("CIM_MVM", dst=1, src=2,
                         rep=d.draw(st.integers(1, 8)),
                         acc=d.draw(st.sampled_from([0, 1])))],
            lambda d: [I("S_ADDI", dst=7, a=0,
                         imm=d.draw(st.integers(1, 300))),
                       I("GLD", dst=1, gaddr=2, size=7)],
            lambda d: [I("S_ADDI", dst=7, a=0,
                         imm=d.draw(st.integers(1, 300))),
                       I("GST", src=1, gaddr=2, size=7)],
            lambda d: [I("S_ADDI", dst=8, a=0,
                         imm=d.draw(st.integers(1, 64))),
                       I("BCAST", src=1, size=8)],
        ])

        class _D:
            draw = staticmethod(draw)

        n_phases = draw(st.integers(1, 2))
        chunks = {c: [] for c in range(_N_CORES)}
        stream = 0
        for phase in range(n_phases):
            sends = {c: [] for c in chunks}
            recvs = {c: [] for c in chunks}
            for _ in range(draw(st.integers(0, 3))):
                src = draw(st.integers(0, _N_CORES - 1))
                dst = draw(st.integers(0, _N_CORES - 1))
                if src == dst:
                    continue
                size = draw(st.integers(1, 96))
                sends[src].extend(_send(src, dst, size, stream))
                recvs[dst].extend(_recv(dst, src, size, stream))
                stream += 1
            for c in chunks:
                ops = []
                for _ in range(draw(st.integers(0, 6))):
                    ops.extend(draw(rng_local)(_D))
                # sends first (never block), then local work, then recvs
                chunks[c].extend(sends[c] + ops + recvs[c])
                chunks[c].append(I("SYNC", barrier=phase))
        programs = {}
        for c, body in chunks.items():
            if draw(st.booleans()):
                body.append(I("HALT",))   # else: END-of-program path
            programs[c] = prog(c, *body)
        return programs

    @settings(max_examples=30, deadline=None)
    @given(stage_programs())
    def test_random_programs_identical(programs):
        assert_identical(*run_stage_both(programs))

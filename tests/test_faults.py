"""Fault injection & graceful degradation (repro.faults).

The invariants pinned here are what make fault numbers trustworthy
rather than anecdotal:

* the same ``FaultModel`` seed resolves to bit-identical fault sets on
  every run — and the corrupted outputs agree bit-exactly across the
  numpy oracle and the functional ISS;
* ``FaultModel(rate=0)`` is an exact no-op on every hook (oracle,
  ISS CIM_LOAD, gmem image, accumulator);
* protection hardware (ECC / spare rows / TMR) lowers the residual
  rate and raises the machine-model cost — and the unprotected chip
  is bit-identical to the pre-protection machine model;
* a mesh plan with a failed chip conserves work exactly and stays
  func-mode bit-exact with the single-chip oracle;
* serving degradation (deadlines, shedding, retries) reports nonzero
  counters under overload with byte-stable metrics JSON — and adds
  no keys at all when switched off.
"""

import json
import warnings

import numpy as np
import pytest

from repro import flow
from repro.core import ref, workloads
from repro.core.arch import ProtectionConfig, default_chip
from repro.core.machine import machine_for
from repro.core.mapping import CostParams
from repro.core.codegen import compile_model
from repro.core.partition import partition
from repro.core.simulator import Simulator
from repro.faults import (FaultModel, FaultSet, PhysicalCimFaults,
                          bit_error_rate, corrupt_gmem,
                          degradation_curve, resolve_faults,
                          residual_rate, top1_agreement)
from repro.flow import CompileOptions
from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                         make_policy, metrics_json, poisson_trace)
from repro.system import SystemConfig, split_pipeline

RNG = np.random.default_rng(11)


def _tiny_setup(batch=2):
    cg = workloads.build("tiny_cnn", res=8, c=8).condense()
    weights, biases, inputs = ref.random_init(cg, batch=batch, seed=3)
    quant = ref.auto_quant(cg, weights, biases, inputs)
    return cg, weights, biases, inputs, quant


# ---------------------------------------------------------------------------
# FaultModel basics
# ---------------------------------------------------------------------------


def test_fault_model_validation_and_roundtrip():
    with pytest.raises(ValueError):
        FaultModel(rate=1.5)
    with pytest.raises(ValueError):
        FaultModel(transient_rate=-0.1)
    with pytest.raises(ValueError):
        FaultModel(seed=-1)
    fm = FaultModel(rate=1e-3, gmem_rate=1e-6, seed=9,
                    failed_chips=(3, 1), failed_links=((2, 0),))
    assert fm.failed_chips == (1, 3)          # normalized sorted
    assert fm.failed_links == ((0, 2),)
    assert FaultModel.from_dict(fm.to_dict()) == fm
    assert FaultModel().is_null
    assert not fm.is_null


def test_same_seed_bit_identical_fault_sets():
    cg, weights, *_ = _tiny_setup()
    chip = default_chip()
    fm = FaultModel(rate=2e-3, seed=42)
    a = resolve_faults(weights, chip, fm)
    b = resolve_faults(weights, chip, fm)
    assert a.counts == b.counts and a.n_stuck > 0
    for gid in a.stuck:
        np.testing.assert_array_equal(a.stuck[gid][0], b.stuck[gid][0])
        np.testing.assert_array_equal(a.stuck[gid][1], b.stuck[gid][1])
    # a different seed draws a different set
    c = resolve_faults(weights, chip, FaultModel(rate=2e-3, seed=43))
    assert any(not np.array_equal(a.stuck[g][0], c.stuck[g][0])
               for g in a.stuck if g in c.stuck) or a.counts != c.counts


def test_corruption_idempotent():
    """Stuck-at faults pin bits: applying the masks twice == once."""
    cg, weights, *_ = _tiny_setup()
    chip = default_chip()
    fs = resolve_faults(weights, chip, FaultModel(rate=5e-3, seed=1))
    for gid, w in weights.items():
        once = fs.corrupt_weight_matrix(gid, w)
        twice = fs.corrupt_weight_matrix(gid, once)
        np.testing.assert_array_equal(once, twice)


def test_rate_zero_is_exact_noop():
    cg, weights, biases, inputs, quant = _tiny_setup()
    chip = default_chip()
    fm = FaultModel(rate=0.0)
    fs = resolve_faults(weights, chip, fm)
    assert fs.n_stuck == 0 and not fs.stuck
    clean = ref.run_reference(cg, weights, biases, quant, inputs)
    faulty = ref.run_reference(cg, weights, biases, quant, inputs,
                               faults=fs)
    for gid in clean:
        np.testing.assert_array_equal(clean[gid], faulty[gid])
    # gmem / accumulator hooks are no-ops too
    img = RNG.integers(-128, 128, 4096).astype(np.int8)
    np.testing.assert_array_equal(corrupt_gmem(img, fm), img)
    acc = RNG.integers(-1000, 1000, (7, 5)).astype(np.int32)
    np.testing.assert_array_equal(fs.corrupt_acc(acc, 0, 0), acc)


# ---------------------------------------------------------------------------
# cross-backend bit-identity of corrupted outputs
# ---------------------------------------------------------------------------


def test_oracle_vs_func_iss_bit_identical_under_faults():
    """The same logical fault set corrupts the numpy oracle and the
    gmem image the ISS executes — outputs must match bit for bit."""
    cg, weights, biases, inputs, quant = _tiny_setup()
    chip = default_chip(n_cores=8, mesh_cols=4)
    fm = FaultModel(rate=2e-3, seed=5)
    fs = resolve_faults(weights, chip, fm)
    assert fs.n_stuck > 0
    oracle = ref.run_reference(cg, weights, biases, quant, inputs,
                               faults=fs)
    res = partition(cg, chip, "dp", CostParams(batch=2))
    model = compile_model(res, batch=2, quant=quant, strict_lmem=True)
    img = model.build_gmem_image(fs.corrupt_weights(weights), biases,
                                 inputs)
    rep = Simulator(chip, model.isa, mode="func").run_model(
        model, gmem_image=img)
    last = len(cg) - 1
    for s in range(2):
        addr, nb = model.output_addr(last, s)
        got = rep.gmem[addr - 0x10000000: addr - 0x10000000 + nb]
        want = oracle[last][s].reshape(-1)
        np.testing.assert_array_equal(got, want.view(np.int8)[:nb])


def test_transient_faults_deterministic_per_sample():
    cg, weights, biases, inputs, quant = _tiny_setup()
    chip = default_chip()
    fm = FaultModel(transient_rate=1e-3, seed=7)
    fs = resolve_faults(weights, chip, fm)
    a = ref.run_reference(cg, weights, biases, quant, inputs, faults=fs)
    b = ref.run_reference(cg, weights, biases, quant, inputs, faults=fs)
    clean = ref.run_reference(cg, weights, biases, quant, inputs)
    for gid in a:
        np.testing.assert_array_equal(a[gid], b[gid])
    assert any(not np.array_equal(a[g], clean[g]) for g in a)


def test_physical_iss_hook_deterministic():
    """Physical (core, mg) stuck bits at CIM_LOAD: same seed -> same
    corrupted outputs; rate=0 -> bit-identical to the fault-free run."""
    cg, weights, biases, inputs, quant = _tiny_setup()
    chip = default_chip(n_cores=8, mesh_cols=4)
    res = partition(cg, chip, "dp", CostParams(batch=2))
    model = compile_model(res, batch=2, quant=quant, strict_lmem=True)
    img = model.build_gmem_image(weights, biases, inputs)

    def run(faults):
        sim = Simulator(chip, model.isa, mode="func", faults=faults)
        return sim.run_model(model, gmem_image=img)

    base = run(None)
    null = run(PhysicalCimFaults(chip, FaultModel(rate=0.0)))
    np.testing.assert_array_equal(base.gmem, null.gmem)
    fm = FaultModel(rate=5e-3, seed=13)
    a = run(PhysicalCimFaults(chip, fm))
    b = run(PhysicalCimFaults(chip, fm))
    np.testing.assert_array_equal(a.gmem, b.gmem)
    assert not np.array_equal(a.gmem, base.gmem)
    # timing never depends on data corruption
    assert a.cycles == base.cycles


def test_gmem_corruption_deterministic():
    img = RNG.integers(-128, 128, 1 << 14).astype(np.int8)
    fm = FaultModel(gmem_rate=1e-3, seed=2)
    a = corrupt_gmem(img, fm)
    b = corrupt_gmem(img, fm)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, img)
    # single-bit flips only: hamming distance per word <= 1
    xor = (a ^ img).view(np.uint32)
    bits = np.unpackbits(xor.view(np.uint8)).reshape(-1, 32).sum(1)
    assert bits.max() == 1


# ---------------------------------------------------------------------------
# mitigation: residual rates down, machine-model costs up
# ---------------------------------------------------------------------------


def test_residual_rates_and_protection_costs():
    macro = default_chip().core.cim.macro
    p = 1e-3
    none = ProtectionConfig()
    assert residual_rate(p, none, macro) == p
    for prot in (ProtectionConfig(tmr=True), ProtectionConfig(ecc=True),
                 ProtectionConfig(spare_rows=4)):
        assert 0.0 <= residual_rate(p, prot, macro) < p
    # spares protect weights, not the datapath
    sp = ProtectionConfig(spare_rows=4)
    assert residual_rate(p, sp, macro, transient=True) == p

    plain = machine_for(default_chip())
    hard = machine_for(default_chip(protection=ProtectionConfig(
        ecc=True, spare_rows=4, tmr=True)))
    assert hard.weight_load_factor > plain.weight_load_factor == 1.0
    assert hard.protection_area_factor > 1.0
    assert hard.mvm_fill_beats > plain.mvm_fill_beats
    # unprotected chip: bit-identical machine model (no silent drift)
    assert plain.weight_load_cycles(128) == \
        machine_for(default_chip()).weight_load_cycles(128)

    fm = FaultModel(rate=p, transient_rate=p)
    mit = fm.mitigated(hard.chip)
    assert mit.rate < fm.rate and mit.transient_rate < fm.transient_rate


def test_degradation_curve_monotone_anchor():
    cg = workloads.build("tiny_cnn", res=8, c=8).condense()
    rows = degradation_curve(cg, default_chip(), [0.0, 0.02], batch=2)
    assert rows[0]["n_stuck"] == 0 and rows[0]["ber"] == 0.0
    assert rows[0]["top1_agreement"] == 1.0
    assert rows[1]["n_stuck"] > 0 and rows[1]["ber"] > 0.0
    # deterministic: same call, same numbers
    again = degradation_curve(cg, default_chip(), [0.0, 0.02], batch=2)
    assert rows == again


# ---------------------------------------------------------------------------
# mesh failover
# ---------------------------------------------------------------------------


def test_degraded_mesh_replan_conserves_work():
    cg = workloads.build("transformer").condense()
    chip = default_chip()
    sysc = SystemConfig.mesh(4).degrade(failed_chips=(2,))
    assert sysc.alive_slots == (0, 1, 3) and sysc.n_alive == 3
    plan = split_pipeline(cg, chip, sysc)
    assert plan.total_macs() == cg.total_macs
    assert all(s.mesh_slot != 2 for s in plan.slices)
    covered = [g for s in plan.slices for g in s.gids]
    assert covered == list(range(len(cg)))


def test_degraded_mesh_func_bit_exact_tiny_cnn():
    """1 failed chip of a 2x2 mesh: the re-planned pipeline still runs
    func-mode bit-exact against the single-chip oracle."""
    sysc = SystemConfig.mesh(4).degrade(failed_chips=(1,))
    art = flow.compile("tiny_cnn", default_chip(), CompileOptions(
        fidelity="func", batch=2, system=sysc))
    assert art.n_chips <= 3
    cg = art.cg
    weights, biases, inputs = ref.random_init(cg, batch=2, seed=17)
    quant = ref.auto_quant(cg, weights, biases, inputs)
    got = art.run_func(weights, biases, inputs, quant=quant)
    oracle = ref.run_reference(cg, weights, biases, quant, inputs)
    last = len(cg) - 1
    for s in range(2):
        np.testing.assert_array_equal(got.final[s],
                                      oracle[last][s].reshape(-1))
    # degraded-mode throughput is reported on the system report
    rep = flow.compile("tiny_cnn", default_chip(), CompileOptions(
        fidelity="analytic", batch=2, system=sysc)).evaluate()
    assert rep.degraded and rep.n_failed_chips == 1
    assert rep.throughput_sps > 0


def test_failed_link_routes_around():
    sysc = SystemConfig(chips_x=2, chips_y=2,
                        failed_links=((0, 1),))
    # snake order on 2x2: 0-1 adjacent; with the link dead the route
    # detours through the other row
    assert sysc.hops(0, 1) == 3
    with pytest.raises(ValueError):
        SystemConfig(chips_x=1, chips_y=1, failed_chips=(0,))


# ---------------------------------------------------------------------------
# serving degradation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serve_table():
    return StepCostTable(ServeModelCfg(), fidelity="analytic")


def test_serving_default_path_unchanged(serve_table):
    tr = poisson_trace(rate=8.0, n=40, seed=0)
    m = ServeSim(serve_table, make_policy("continuous", 8)).run(tr)
    for k in ("shed_requests", "timeout_requests", "retries",
              "goodput_tok_s"):
        assert k not in m
    # degraded config with unreachable limits: identical core metrics
    m2 = ServeSim(serve_table, make_policy("continuous", 8),
                  deadline_s=1e9, max_queue=10 ** 9).run(tr)
    assert m2["shed_requests"] == 0 and m2["timeout_requests"] == 0
    for k in m:
        assert m[k] == m2[k]


def test_serving_degradation_counters_byte_stable(serve_table):
    # well over the ~90k req/s prefill capacity of the analytic table
    hot = poisson_trace(rate=300000.0, n=200, seed=1)
    kw = dict(deadline_s=0.002, max_queue=4, max_retries=2,
              retry_backoff_s=0.0005)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        a = ServeSim(serve_table, make_policy("continuous", 8),
                     **kw).run(hot)
        b = ServeSim(serve_table, make_policy("continuous", 8),
                     **kw).run(hot)
    assert a["shed_requests"] > 0
    assert a["timeout_requests"] > 0
    assert a["retries"] > 0
    assert a["goodput_tok_s"] < a["throughput_tok_s"]
    assert a["requests"] + a["shed_requests"] == len(hot)
    assert metrics_json(a) == metrics_json(b)
    json.loads(metrics_json(a))   # stays valid canonical JSON


def test_serving_saturation_warning_and_cap(serve_table):
    hot = poisson_trace(rate=300000.0, n=100, seed=2)
    sim = ServeSim(serve_table, make_policy("continuous", 8))
    with pytest.warns(RuntimeWarning, match="saturated"):
        sim.run(hot)
    with pytest.raises(RuntimeError, match="max_sim_s"):
        sim.run(hot, max_sim_s=1e-4)

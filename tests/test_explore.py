"""repro.explore subsystem: design space, cache determinism, search
strategies, Pareto dominance, and engine/legacy-path equivalence."""

import math

import pytest

from repro.core import workloads
from repro.core.mapping import CostParams
from repro.explore import (DesignPoint, DesignSpace, Dimension,
                           EvalRecord, ExplorationEngine, RecordStore,
                           ResultCache, annotate, by_edp, cache_key,
                           grid_search, hill_climb, mg_flit_space,
                           pareto_frontier, random_search,
                           successive_halving)

MODEL = "tiny_cnn"
KW = dict(res=8)
PARAMS = CostParams(batch=2)


def make_engine(pool=0, cache=None, store=None):
    return ExplorationEngine(MODEL, params=PARAMS, pool=pool,
                             cache=cache, store=store, **KW)


def toy_space():
    return mg_flit_space((4, 8), (8, 16))     # 4 valid points


# ---------------------------------------------------------------------------
# design space
# ---------------------------------------------------------------------------


def test_space_enumerates_valid_grid():
    sp = toy_space()
    pts = sp.points()
    assert len(pts) == 4 == len(sp)
    assert len(set(pts)) == 4
    for pt in pts:
        chip = pt.chip()     # must construct without ArchError
        assert chip.core.cim.macros_per_group == pt.macros_per_group
        assert chip.noc.flit_bytes == pt.flit_bytes
        assert pt in sp


def test_space_constraints_filter_points():
    sp = DesignSpace([Dimension("macros_per_group", (4, 8, 16))],
                     constraints=[lambda p: p.macros_per_group <= 8])
    assert [p.macros_per_group for p in sp] == [4, 8]


def test_space_mutation_stays_valid():
    import random
    sp = toy_space()
    rng = random.Random(0)
    pt = sp.random_point(rng)
    for _ in range(20):
        new = sp.mutate(pt, rng)
        assert new in sp and new != pt
        pt = new


def test_point_roundtrip_and_macro_count():
    pt = DesignPoint(macros_per_group=4, n_macro_groups=8, n_cores=16)
    assert DesignPoint.from_dict(pt.to_dict()) == pt
    assert pt.total_macros == 16 * 8 * 4


# ---------------------------------------------------------------------------
# pareto
# ---------------------------------------------------------------------------


def _rec(cycles, energy, mg=8):
    return EvalRecord(point=DesignPoint(macros_per_group=mg),
                      model=MODEL, fidelity="analytic", cycles=cycles,
                      throughput_sps=1.0,
                      energy={"total": energy})


def test_pareto_frontier_hand_built():
    recs = [
        _rec(10, 100, mg=2),    # frontier (best cycles)
        _rec(20, 50, mg=4),     # frontier
        _rec(40, 20, mg=8),     # frontier (best energy)
        _rec(25, 60, mg=16),    # dominated by (20, 50)
        _rec(50, 120, mg=16),   # dominated by everything
    ]
    front = pareto_frontier(recs, axes=("cycles", "energy"))
    assert [(r.cycles, r.energy_total) for r in front] == \
        [(10, 100), (20, 50), (40, 20)]

    meta = {p.record.cycles: p for p in annotate(recs)}
    assert meta[25].dominated_by == 1 and not meta[25].on_frontier
    assert meta[50].dominated_by == 4 and meta[50].rank > 0
    assert all(meta[c].rank == 0 for c in (10, 20, 40))


def test_pareto_three_objectives_and_errors():
    good = _rec(10, 100)
    bad = _rec(math.inf, math.inf)
    bad.error = "InfeasibleModel: nope"
    front = pareto_frontier([good, bad], axes=("cycles", "energy",
                                               "macros"))
    assert front == [good]


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def test_cache_key_deterministic_and_discriminating():
    pt = DesignPoint()
    k1 = cache_key(MODEL, pt.chip(), "generic", "analytic", PARAMS)
    k2 = cache_key(MODEL, pt.chip(), "generic", "analytic", PARAMS)
    assert k1 == k2
    assert k1 != cache_key(MODEL, pt.chip(), "dp", "analytic", PARAMS)
    assert k1 != cache_key(MODEL, pt.chip(), "generic", "simulate",
                           PARAMS)
    other = pt.replace(flit_bytes=16).chip()
    assert k1 != cache_key(MODEL, other, "generic", "analytic", PARAMS)
    # cosmetic chip names must not split cache entries
    import dataclasses
    renamed = dataclasses.replace(pt.chip(), name="whatever")
    assert k1 == cache_key(MODEL, renamed, "generic", "analytic", PARAMS)


def test_cache_hit_miss_and_identical_records(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    eng = make_engine(cache=cache)
    sp = toy_space()
    first = eng.sweep(sp)
    assert all(not r.cache_hit for r in first)
    assert cache.misses == len(first) and cache.hits == 0
    assert len(cache) == len(first)

    second = eng.sweep(sp)
    assert all(r.cache_hit for r in second)
    for a, b in zip(first, second):
        assert a.point == b.point
        assert a.cycles == b.cycles
        assert a.energy == b.energy
        assert a.throughput_sps == b.throughput_sps

    # a fresh engine over the same cache dir also hits
    eng2 = make_engine(cache=ResultCache(str(tmp_path / "cache")))
    third = eng2.sweep(sp)
    assert all(r.cache_hit for r in third)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def test_engine_matches_legacy_dse_evaluate():
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import dse
    cg = workloads.build(MODEL, **KW).condense()
    recs = make_engine().sweep(toy_space())
    for rec in recs:
        legacy = dse.evaluate(cg, rec.point.chip(), rec.point.strategy,
                              PARAMS, simulate=False)
        assert rec.cycles == legacy.cycles
        assert rec.energy == legacy.energy
        assert rec.throughput_sps == pytest.approx(
            legacy.throughput_sps)


def test_engine_pool_matches_serial():
    sp = toy_space()
    serial = make_engine(pool=0).sweep(sp)
    pooled = make_engine(pool=2).sweep(sp)
    assert [r.point for r in serial] == [r.point for r in pooled]
    for a, b in zip(serial, pooled):
        assert a.cycles == b.cycles and a.energy == b.energy


def test_engine_survives_infeasible_points():
    # transformer attention needs dynamic weights; a 1-core chip with
    # minimal CIM capacity cannot host resnet18 at res 112 in one pass —
    # but rather than constructing a guaranteed failure we inject one
    # via a point whose chip() violates mapping assumptions at runtime.
    eng = ExplorationEngine("transformer", params=CostParams(batch=1),
                            pool=0, cache=None, n_layers=1, d_model=64,
                            n_heads=2, seq=8)
    pts = [DesignPoint(macros_per_group=2, n_macro_groups=8,
                       n_cores=16, local_mem_kb=256)]
    recs = eng.evaluate(pts)
    assert len(recs) == 1      # never raises out of evaluate()
    r = recs[0]
    assert r.ok or (math.isinf(r.cycles) and r.error)


def test_engine_invalid_chip_point_errors_on_both_cache_paths(tmp_path):
    # chip() itself raises ArchError for flit_bytes=0; the cache path
    # keys points via chip() in the parent, so this must degrade to an
    # error record there too, not just in the worker
    bad = DesignPoint(flit_bytes=0)
    good = DesignPoint()
    for cache in (None, ResultCache(str(tmp_path / "c"))):
        recs = make_engine(cache=cache).evaluate([bad, good])
        assert not recs[0].ok and math.isinf(recs[0].cycles)
        assert "ArchError" in recs[0].error
        assert recs[1].ok and math.isfinite(recs[1].cycles)


def test_record_store_roundtrip(tmp_path):
    path = str(tmp_path / "out" / "trace.jsonl")
    store = RecordStore(path)
    eng = make_engine(store=store)
    recs = eng.sweep(toy_space())
    loaded = store.load()
    assert len(loaded) == len(recs)
    for a, b in zip(recs, loaded):
        assert a.point == b.point and a.cycles == b.cycles
        assert a.to_dict() == b.to_dict()


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------


def test_random_and_hillclimb_find_known_best():
    eng = make_engine()
    sp = toy_space()
    exhaustive = grid_search(eng, sp, objective=by_edp)
    best_point = exhaustive.best.point

    rnd = random_search(eng, sp, n=len(sp), objective=by_edp, seed=3)
    assert rnd.best.point == best_point

    hc = hill_climb(eng, sp, objective=by_edp, seed=1, iters=12,
                    neighbors=3, restarts=3)
    assert hc.best.point == best_point
    assert hc.n_evals <= len(sp)      # seen-set dedup on a tiny space


def test_successive_halving_promotes_to_simulator():
    eng = make_engine()
    res, screened = successive_halving(eng, toy_space(), top_k=2,
                                       objective=by_edp)
    assert len(screened) == 4
    assert all(r.fidelity == "analytic" for r in screened)
    assert len(res.history) == 2
    assert all(r.fidelity == "simulate" for r in res.history)
    # the winner is one of the analytic top-2
    ranked = sorted(screened, key=by_edp)[:2]
    assert res.best.point in {r.point for r in ranked}


# ---------------------------------------------------------------------------
# cache eviction
# ---------------------------------------------------------------------------


def _seed_cache(tmp_path, n=5, t0=1_000_000.0):
    """Cache with n entries whose mtimes are one day apart."""
    import os
    cache = ResultCache(str(tmp_path / "evict"))
    paths = []
    for i in range(n):
        key = cache_key(f"m{i}", DesignPoint().chip(), "dp", "analytic")
        cache.put(key, {"cycles": float(i)})
        path = cache._path(key)
        os.utime(path, (t0 + 86400 * i, t0 + 86400 * i))
        paths.append(path)
    return cache, paths, t0


def test_cache_prune_by_age(tmp_path):
    import os
    cache, paths, t0 = _seed_cache(tmp_path)
    now = t0 + 4 * 86400 + 10        # entries 0..3 are > 1 day old
    removed = cache.prune(max_age_days=1, now=now)
    assert removed == 4
    assert len(cache) == 1
    assert os.path.exists(paths[4]) and not os.path.exists(paths[0])


def test_cache_prune_by_count_keeps_newest(tmp_path):
    import os
    cache, paths, _ = _seed_cache(tmp_path)
    removed = cache.prune(max_entries=2)
    assert removed == 3
    assert len(cache) == 2
    assert os.path.exists(paths[3]) and os.path.exists(paths[4])
    assert not os.path.exists(paths[1])


def test_cache_prune_policy_from_constructor(tmp_path):
    cache, _, t0 = _seed_cache(tmp_path)
    now = t0 + 4 * 86400 + 10
    cache2 = ResultCache(cache.root, max_age_days=1, max_entries=1)
    assert cache2.prune(now=now) == 4           # age evicts 0..3
    assert cache2.prune(now=now) == 0           # nothing left to evict
    cache.put(cache_key("x", DesignPoint().chip(), "dp", "analytic"),
              {"cycles": 1.0})                  # fresh mtime
    assert cache2.prune(now=now) == 1           # count cap kicks in
    assert len(cache2) == 1


def test_cache_prune_noop_without_limits(tmp_path):
    cache, _, _ = _seed_cache(tmp_path)
    assert cache.prune() == 0
    assert len(cache) == 5


# ---------------------------------------------------------------------------
# CLI (python -m repro.explore)
# ---------------------------------------------------------------------------


def test_cli_sweep_and_pareto(tmp_path, capsys):
    from repro.explore.cli import main
    store = str(tmp_path / "sweep.jsonl")
    rc = main(["sweep", "tiny_cnn", "--res", "8", "--batch", "2",
               "--mg", "4,8", "--flit", "8", "--strategies",
               "generic,dp", "--no-cache", "--store", store])
    assert rc == 0
    out = capsys.readouterr().out
    assert "tiny_cnn" in out and "dp" in out
    assert len(RecordStore(store)) == 4

    rc = main(["pareto", store, "--axes", "cycles,energy"])
    assert rc == 0
    assert "frontier" in capsys.readouterr().out


def test_cli_cache_prune_and_stats(tmp_path, capsys):
    from repro.explore.cli import main
    cache, _, _ = _seed_cache(tmp_path)
    rc = main(["cache", "stats", "--cache-root", cache.root])
    assert rc == 0
    assert "5 entries" in capsys.readouterr().out
    rc = main(["cache", "prune", "--cache-root", cache.root,
               "--max-entries", "1"])
    assert rc == 0
    assert "pruned 4 entries" in capsys.readouterr().out
    assert len(cache) == 1
    with pytest.raises(SystemExit):
        main(["cache", "prune", "--cache-root", cache.root])


def test_engine_promotion_reuses_partition_pass(monkeypatch):
    """Successive halving through the engine must hit the flow
    pipeline's partition cache when promoting to the simulator."""
    from repro import flow
    from repro.flow import passes as flow_passes
    flow.default_pipeline().clear_cache()
    calls = []
    orig = flow_passes._partition
    monkeypatch.setattr(
        flow_passes, "_partition",
        lambda *a, **kw: (calls.append(a), orig(*a, **kw))[1])
    eng = make_engine()          # serial, no result cache
    successive_halving(eng, mg_flit_space((4,), (8,)), top_k=1)
    # 1 point x (analytic screen + simulator promotion): the promotion
    # must reuse the screen's partition, so exactly one computation
    assert len(calls) == 1

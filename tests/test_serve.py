"""Serving-simulator suite: repro.serve on the CIM fidelity ladder.

Covers the ISSUE-6 acceptance surface:

* determinism — same trace + seed produce byte-identical metrics JSON;
* fidelity agreement — decode-step trace cycles stay inside the
  documented trace band of the perf simulator on a tiny config;
* the incremental (append-row) KV path — per-decode-step marginal cost
  is O(1) in KV length (so a full generation is O(seq), not O(seq²)),
  and strictly cheaper than full re-staging;
* length-bucketed admission (tensor2tensor ``data_reader`` idiom);
* continuous (iteration-level) batching beats static batching on p99
  per-token latency at equal offered load near saturation;
* KV admission control never overshoots its budget.
"""

import json

import pytest

from repro import flow
from repro.core.arch import default_chip
from repro.flow import CompileOptions
from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                         bucket_batch_sizes, bucket_boundaries,
                         bucket_for, bursty_trace, group_by_bucket,
                         load_trace, make_policy, metrics_json,
                         percentile, poisson_trace, save_trace)

# trace / perf agreement band, as documented in tests/test_fidelity.py
TRACE_BAND = (0.5, 2.0)

TINY = dict(n_layers=1, d_model=64, n_heads=2, vocab=64)


@pytest.fixture(scope="module")
def chip():
    return default_chip()


@pytest.fixture(scope="module")
def table(chip):
    cfg = ServeModelCfg(max_prompt=16, max_new=16, **TINY)
    return StepCostTable(cfg, chip=chip, fidelity="trace")


def _decode_cycles(chip, kv_len, batch, incremental, fidelity="trace"):
    kw = dict(kv_len=kv_len, incremental=incremental, **TINY)
    art = flow.compile("transformer_decode", chip, CompileOptions(
        workload_kw=kw, fidelity=fidelity, batch=batch))
    return float(art.evaluate().cycles)


# --------------------------------------------------------------------
# Bucketing
# --------------------------------------------------------------------

def test_bucket_boundaries_cover_range():
    bs = bucket_boundaries(100, min_length=8, step=1.25)
    assert bs[0] == 8 and bs[-1] == 100
    assert all(b2 > b1 for b1, b2 in zip(bs, bs[1:]))


def test_bucket_boundaries_small_max():
    assert bucket_boundaries(4) == [4]
    with pytest.raises(ValueError):
        bucket_boundaries(0)
    with pytest.raises(ValueError):
        bucket_boundaries(16, step=1.0)


def test_bucket_for_edges():
    bs = [8, 16, 32]
    assert bucket_for(0, bs) == 8
    assert bucket_for(8, bs) == 8
    assert bucket_for(9, bs) == 16
    assert bucket_for(32, bs) == 32
    with pytest.raises(ValueError):
        bucket_for(33, bs)
    with pytest.raises(ValueError):
        bucket_for(-1, bs)


def test_bucket_batch_sizes_token_budget():
    sizes = bucket_batch_sizes([8, 16, 32], tokens_per_batch=64,
                               max_batch=16)
    assert sizes == {8: 8, 16: 4, 32: 2}
    # budget smaller than a bucket still admits one request
    assert bucket_batch_sizes([128], 64, 16) == {128: 1}


def test_group_by_bucket():
    groups = group_by_bucket([3, 9, 20, 8], [8, 16, 32])
    assert groups == {8: [0, 3], 16: [1], 32: [2]}


def test_percentile_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    assert percentile([], 99) == 0.0


# --------------------------------------------------------------------
# Incremental (append-row) decode cost path
# --------------------------------------------------------------------

def test_incremental_step_cost_flat_in_kv_len(chip):
    """Marginal decode-step cost must not scale with KV length."""
    steps = {}
    for kv in (32, 128):
        c1 = _decode_cycles(chip, kv, 1, incremental=True)
        c8 = _decode_cycles(chip, kv, 8, incremental=True)
        steps[kv] = (c8 - c1) / 7.0
    # 4x the KV length may not even double the per-step cost (the
    # residual growth is the attention MVM itself, which is O(kv));
    # the O(kv) weight re-staging this bounds would give ~4x.
    assert steps[128] < 2.0 * steps[32]


def test_full_restage_scales_with_kv_len(chip):
    """Control: without kv_append the per-step cost is O(kv_len)."""
    steps = {}
    for kv in (32, 128):
        c1 = _decode_cycles(chip, kv, 1, incremental=False)
        c8 = _decode_cycles(chip, kv, 8, incremental=False)
        steps[kv] = (c8 - c1) / 7.0
    assert steps[128] > 2.5 * steps[32]


def test_incremental_beats_full_restage(chip):
    for kv in (32, 128):
        incr = _decode_cycles(chip, kv, 8, incremental=True)
        full = _decode_cycles(chip, kv, 8, incremental=False)
        assert incr < full


def test_decode_trace_within_band_of_simulator(chip):
    """Fidelity agreement on the decode step (tiny config)."""
    tr = _decode_cycles(chip, 32, 4, True, fidelity="trace")
    pf = _decode_cycles(chip, 32, 4, True, fidelity="simulate")
    assert TRACE_BAND[0] <= tr / pf <= TRACE_BAND[1]


# --------------------------------------------------------------------
# Traces
# --------------------------------------------------------------------

def test_trace_generators_deterministic():
    a = poisson_trace(100.0, 50, seed=7)
    b = poisson_trace(100.0, 50, seed=7)
    assert a == b
    assert poisson_trace(100.0, 50, seed=8) != a
    assert bursty_trace(100.0, 50, seed=7) == bursty_trace(
        100.0, 50, seed=7)


def test_trace_roundtrip(tmp_path):
    a = poisson_trace(100.0, 20, seed=3)
    path = str(tmp_path / "trace.json")
    save_trace(path, a)
    assert load_trace(path) == a


def test_bursty_rejects_bad_duty():
    with pytest.raises(ValueError):
        bursty_trace(100.0, 10, duty=0.0)
    with pytest.raises(ValueError):
        bursty_trace(100.0, 10, burst=10.0, duty=0.5)


# --------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------

def _mk_trace(table, rate_x, n=80, seed=0):
    """Trace whose offered token load is rate_x times decode capacity."""
    cfg = table.cfg
    cap = table.fit_batch / table.iteration_s(
        [cfg.max_seq] * table.fit_batch)
    avg_gen = (4 + cfg.max_new) / 2.0
    rate = rate_x * cap / avg_gen
    return poisson_trace(rate, n, seed=seed, max_prompt=cfg.max_prompt,
                         max_new=cfg.max_new)


def test_metrics_json_deterministic(table):
    trace = _mk_trace(table, 0.8)
    runs = []
    for _ in range(2):
        sim = ServeSim(table, make_policy("continuous", 8))
        runs.append(metrics_json(sim.run(trace)))
    assert runs[0] == runs[1]
    payload = json.loads(runs[0])
    for key in ("ttft_s", "tpot_s", "e2e_s"):
        assert {"p50", "p95", "p99", "mean"} <= set(payload[key])
    assert payload["requests"] == 80


def test_all_tokens_accounted(table):
    trace = _mk_trace(table, 0.5, n=40)
    m = ServeSim(table, make_policy("continuous", 8)).run(trace)
    assert m["tokens"] == sum(r.gen_len for r in trace)
    assert m["throughput_tok_s"] > 0


def test_continuous_beats_static_p99_at_equal_throughput(table):
    """Near saturation, iteration-level batching wins tail latency."""
    trace = _mk_trace(table, 1.2, n=120)
    ms = ServeSim(table, make_policy("static", 8)).run(trace)
    mc = ServeSim(table, make_policy("continuous", 8)).run(trace)
    # same trace fully served -> comparable delivered throughput
    assert mc["tokens"] == ms["tokens"]
    assert mc["throughput_tok_s"] >= 0.95 * ms["throughput_tok_s"]
    assert mc["tpot_s"]["p99"] < ms["tpot_s"]["p99"]
    assert mc["e2e_s"]["p99"] <= ms["e2e_s"]["p99"]


def test_kv_admission_respects_budget(table):
    cfg = table.cfg
    one = cfg.kv_bytes(cfg.max_seq)
    # all-max-length requests each reserve exactly `one`, so a budget
    # of two max-length requests caps decode concurrency at 2
    trace = poisson_trace(
        1e5, 40, seed=0,
        min_prompt=cfg.max_prompt, max_prompt=cfg.max_prompt,
        min_new=cfg.max_new, max_new=cfg.max_new)
    sim = ServeSim(table, make_policy("continuous", 8),
                   kv_capacity_bytes=2 * one)
    m = sim.run(trace)
    assert m["kv_peak_bytes"] <= 2 * one
    assert m["peak_decode_batch"] <= 2


def test_kv_budget_too_small_rejected(table):
    one = table.cfg.kv_bytes(table.cfg.max_seq)
    with pytest.raises(ValueError):
        ServeSim(table, make_policy("continuous", 8),
                 kv_capacity_bytes=one - 1)


def test_single_token_requests_skip_decode(table):
    trace = [r for r in _mk_trace(table, 0.5, n=10)]
    trace = [type(r)(rid=r.rid, t_arrive=r.t_arrive,
                     prompt_len=r.prompt_len, gen_len=1)
             for r in trace]
    m = ServeSim(table, make_policy("continuous", 8)).run(trace)
    assert m["decode_iterations"] == 0
    assert m["tokens"] == 10

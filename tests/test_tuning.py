"""§Perf tuning knobs must preserve semantics (within quantization
tolerance) — hillclimb wins that break the model don't count."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.launch import meshctx, sharding, tuning
from repro.launch.mesh import make_mesh
from repro.models import model_zoo, transformer as T

BATCH, SEQ = 2, 32


def _build(name):
    cfg = reduced(ARCHS[name])
    params = model_zoo.init(cfg)
    batch = model_zoo.dummy_batch(cfg, BATCH, SEQ)
    return cfg, params, batch


def test_int8_kv_cache_decode_close():
    cfg, params, batch = _build("h2o-danube-3-4b")
    ref = np.asarray(T.forward(cfg, params, batch, remat=False)[:, -1])
    with tuning.tuned(int8_kv_cache=True):
        state = T.init_decode_state(cfg, params, BATCH, SEQ)
        assert state["caches"]["attn0"]["k"].dtype == jnp.int8
        logits = None
        for t in range(SEQ):
            logits, state = T.decode_step(cfg, params, state,
                                          batch["tokens"][:, t:t + 1])
    # int8 cache: small quantization error, same predictions
    np.testing.assert_allclose(np.asarray(logits), ref, rtol=0.3,
                               atol=0.5)
    assert (np.argmax(np.asarray(logits), -1)
            == np.argmax(ref, -1)).mean() >= 0.5


def test_seq_parallel_attention_exact_on_trivial_mesh():
    """With |model| == 1 the reshards are no-ops -> bit-close output."""
    cfg, params, batch = _build("phi3-medium-14b")
    ref = np.asarray(T.forward(cfg, params, batch, remat=False))
    mesh = make_mesh((1, 1), ("data", "model"))
    with meshctx.use_mesh(mesh, data_axes=("data",)), \
            tuning.tuned(attn_seq_parallel=True):
        out = np.asarray(T.forward(cfg, params, batch, remat=False))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_fsdp_specs_shard_more():
    from repro.launch import steps
    cfg = ARCHS["phi4-mini-3.8b"]
    mesh = make_mesh((1, 1), ("data", "model"))
    base = sharding.param_specs(cfg, mesh)
    fsdp = sharding.fsdp_specs(base, steps.abstract_params(cfg), mesh)
    n_base = sum("data" in str(s) for s in jax.tree.leaves(
        base, is_leaf=lambda x: isinstance(x, P)))
    n_fsdp = sum("data" in str(s) for s in jax.tree.leaves(
        fsdp, is_leaf=lambda x: isinstance(x, P)))
    assert n_fsdp > n_base


def test_int8_weights_abstract_params():
    from repro.launch import steps
    cfg = reduced(ARCHS["olmoe-1b-7b"])
    with tuning.tuned(int8_weights=True):
        tree = steps.abstract_params(cfg)
    leaves = jax.tree.leaves(tree)
    assert any(l.dtype == jnp.int8 for l in leaves if l.ndim >= 2)
    assert all(l.dtype != jnp.int8 for l in leaves if l.ndim < 2)


def test_int8_weights_forward_finite():
    cfg, params, batch = _build("phi4-mini-3.8b")
    # quantize the params the way the knob stores them
    def q(a):
        if hasattr(a, "ndim") and a.ndim >= 2 and \
                jnp.issubdtype(a.dtype, jnp.floating):
            return jnp.clip(jnp.round(a * 128), -127, 127).astype(jnp.int8)
        return a
    qparams = jax.tree.map(q, params)
    with tuning.tuned(int8_weights=True):
        logits = T.forward(cfg, qparams, batch, remat=False)
    assert np.isfinite(np.asarray(logits)).all()

"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED config of the same
family and runs, on CPU:

* one forward pass (shape + finiteness),
* one loss/grad evaluation (trainability),
* step-by-step decode vs full forward (KV-cache / ring-SWA / MLA-latent /
  SSD-state consistency) — the decode paths must agree with the parallel
  formulation to ~fp32 tolerance.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models import model_zoo, transformer as T

BATCH, SEQ = 2, 32

NO_DECODE_CONSISTENCY = {
    # vision prefix shifts decode positions; exercised via forward only
    "llava-next-mistral-7b",
}


@pytest.fixture(scope="module")
def built():
    out = {}
    for name, full in ARCHS.items():
        cfg = reduced(full)
        params = model_zoo.init(cfg)
        batch = model_zoo.dummy_batch(cfg, BATCH, SEQ)
        out[name] = (cfg, params, batch)
    return out


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finite(built, name):
    cfg, params, batch = built[name]
    logits = T.forward(cfg, params, batch, remat=False)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_loss_and_grad_finite(built, name):
    cfg, params, batch = built[name]
    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g)).all()
                          for g in leaves)


@pytest.mark.parametrize("name", sorted(set(ARCHS)
                                        - NO_DECODE_CONSISTENCY))
def test_decode_matches_forward(built, name):
    """Token-by-token decode reproduces the parallel forward pass."""
    cfg, params, batch = built[name]
    logits_full = np.asarray(
        T.forward(cfg, params, batch, remat=False)[:, -1], np.float32)
    enc = None
    if cfg.encoder_layers:
        enc = T._run_encoder(cfg, params, batch["frames"])
    state = T.init_decode_state(cfg, params, BATCH, SEQ, enc=enc)
    step = jax.jit(lambda st, tok: T.decode_step(cfg, params, st, tok))
    logits = None
    for t in range(SEQ):
        logits, state = step(state, batch["tokens"][:, t:t + 1])
    np.testing.assert_allclose(np.asarray(logits), logits_full,
                               rtol=2e-2, atol=2e-3)


def test_sliding_window_ring_cache_smaller_than_seq():
    """SWA cache holds only `window` slots yet matches full forward."""
    cfg = reduced(ARCHS["h2o-danube-3-4b"])
    assert cfg.sliding_window == 16 and SEQ > cfg.sliding_window
    params = model_zoo.init(cfg)
    batch = model_zoo.dummy_batch(cfg, BATCH, SEQ)
    assert T.cache_len_for(cfg, SEQ) == 16
    # covered by test_decode_matches_forward; here assert cache geometry
    state = T.init_decode_state(cfg, params, BATCH, SEQ)
    assert state["caches"]["attn0"]["k"].shape[2] == 16


def test_flash_attention_matches_naive():
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    b, s, kv, g, d = 2, 256, 2, 2, 16
    q = jax.random.normal(key, (b, s, kv, g, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, d))

    def causal(qi, ki):
        return ki <= qi

    naive = L._gqa_scores_ctx(q, k, v, causal, 0)
    flash = L.flash_attention(q, k, v, causal, block_q=64, block_k=32)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_sliding_window():
    from repro.models import layers as L
    key = jax.random.PRNGKey(3)
    b, s, kv, g, d = 1, 192, 1, 2, 8
    q = jax.random.normal(key, (b, s, kv, g, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, d))
    win = 37

    def mfn(qi, ki):
        return (ki <= qi) & (ki > qi - win)

    naive = L._gqa_scores_ctx(q, k, v, mfn, 0)
    flash = L.flash_attention(q, k, v, mfn, block_q=48, block_k=64)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive),
                               rtol=1e-5, atol=1e-5)


def test_ssd_chunked_matches_naive_recurrence():
    """Chunked SSD == step-by-step recurrence on a tiny config."""
    from repro.models import ssm as S
    cfg = reduced(ARCHS["mamba2-780m"])
    params = model_zoo.init(cfg)
    bp = jax.tree.map(lambda a: a[0], params["blocks"])
    p = bp["ssm0"]
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 32, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunked = S.ssm_apply(cfg, p, x)
    state = S.ssm_state_init(cfg, 2, jnp.float32)
    ys = []
    for t in range(32):
        y, state = S.ssm_decode(cfg, p, x[:, t:t + 1], state)
        ys.append(y)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked),
                               np.asarray(y_steps), rtol=2e-3, atol=2e-4)

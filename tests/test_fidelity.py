"""Fidelity-agreement suite: analytic vs trace vs perf-mode simulator.

Pins the documented ratio bands of the fidelity ladder on the golden
workloads (tiny_cnn, resnet18@112), asserts the trace fidelity's
contract (within 2x of perf cycles, still several times faster than
even the vectorized perf engine, no codegen), and
encodes the calibration gap test: calibrated analytic screening must
rank the fig6 arch sweep like the simulator does (top-3 agreement).
"""

import time
import warnings

import pytest

from repro import flow
from repro.core.arch import default_chip
from repro.core.machine import Calibration
from repro.core.mapping import CostParams
from repro.flow import BACKENDS, CompileOptions, backend_for_fidelity

pytestmark = pytest.mark.filterwarnings(
    "ignore:perf-mode lmem overflow:RuntimeWarning")

GOLDEN = (
    ("tiny_cnn", {}, "dp"),
    ("tiny_cnn", {}, "generic"),
    ("resnet18", {"res": 112}, "dp"),
    ("resnet18", {"res": 112}, "generic"),
)

# Documented bands (golden workloads, default chip, batch=4):
# perf / analytic stays within [1, 16] — the raw analytic model is
# optimistic (it idealizes im2col gather and handoff serialization)
# but never by more than ~13x here; trace / perf stays within [1/2, 2].
ANALYTIC_BAND = (1.0, 16.0)
TRACE_BAND = (0.5, 2.0)
# trace vs the *vectorized* perf engine (PR 4 closed most of the old
# 40-290x interpreter gap; ~10x remains on resnet18@112/dp, asserted
# loosely so CI timing noise cannot flake the suite)
TRACE_MIN_SPEEDUP = 4.0


@pytest.fixture(scope="module")
def chip():
    return default_chip()


def _timed(fn, reps=2):
    """(result, best wall) — min over ``reps`` runs, gc parked.

    The walls here feed a ratio assertion on measurements tens of ms
    long; a gen-2 garbage collection landing inside one of them (the
    fixture compiles whole artifacts right before timing, so the heap
    is at its deepest) skews the ratio by several x.  Collect up front
    and keep the best of two runs so the assertion sees engine speed,
    not allocator state.
    """
    import gc
    gc.collect()
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


@pytest.fixture(scope="module")
def golden(chip):
    """{(model, strategy): {fidelity: cycles, *_wall_s}} on batch=4."""
    out = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for model, kw, strategy in GOLDEN:
            art = flow.compile(
                model, chip,
                CompileOptions(strategy=strategy,
                               params=CostParams(batch=4),
                               workload_kw=kw or None))
            row = {}
            row["analytic"] = art.evaluate("analytic").cycles
            tr, row["trace_wall_s"] = _timed(
                lambda: art.evaluate("trace"))
            row["trace"] = tr.cycles
            art.ensure_model()          # keep codegen out of the timing
            sim, row["perf_wall_s"] = _timed(
                lambda: art.evaluate("simulate"))
            row["perf"] = sim.cycles
            out[(model, strategy)] = row
    return out


def test_trace_backend_registered():
    assert "trace" in BACKENDS
    assert backend_for_fidelity("trace") == "trace"
    assert "trace" in flow.FIDELITIES


def test_trace_needs_no_codegen(chip):
    art = flow.compile("tiny_cnn", chip,
                       CompileOptions(fidelity="trace",
                                      params=CostParams(batch=2)))
    rep = art.evaluate()
    assert rep.backend == "trace"
    assert rep.trace is not None and rep.trace.n_events > 0
    assert art.model is None            # replay never lowered to ISA


def test_trace_within_band_of_perf(golden):
    for key, row in golden.items():
        ratio = row["trace"] / row["perf"]
        assert TRACE_BAND[0] <= ratio <= TRACE_BAND[1], \
            f"{key}: trace/perf = {ratio:.2f} outside {TRACE_BAND}"


def test_analytic_within_documented_band(golden):
    for key, row in golden.items():
        ratio = row["perf"] / row["analytic"]
        assert ANALYTIC_BAND[0] <= ratio <= ANALYTIC_BAND[1], \
            f"{key}: perf/analytic = {ratio:.2f} outside {ANALYTIC_BAND}"


def test_trace_speedup(golden):
    # the big workload is where speed matters (and where timing noise
    # cannot swamp the measurement)
    row = golden[("resnet18", "dp")]
    speedup = row["perf_wall_s"] / max(row["trace_wall_s"], 1e-9)
    assert speedup >= TRACE_MIN_SPEEDUP, \
        f"trace only {speedup:.0f}x faster than perf"


def test_fidelity_ladder_ordering(golden):
    # cheap fidelities bracket the simulator from below on the golden
    # set: analytic <= trace everywhere (trace adds the serialization
    # the analytic model idealizes away)
    for key, row in golden.items():
        assert row["analytic"] <= row["trace"] * 1.001, key


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def calib_reports(chip):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        wl = [("tiny_cnn", {}), ("resnet18", {"res": 112})]
        ana = flow.calibrate(wl, chip, params=CostParams(batch=4))
        tra = flow.calibrate(wl, chip, params=CostParams(batch=4),
                             fidelity="trace")
    return ana, tra


def test_calibration_tightens_analytic(calib_reports):
    ana, _ = calib_reports
    assert ana.max_ratio(calibrated=True) < ana.max_ratio(False)
    assert ana.max_ratio(calibrated=True) <= 2.0
    # the fit must have learned that vector work is underestimated
    assert ana.calibration.vector > 2.0


def test_calibration_tightens_trace(calib_reports):
    _, tra = calib_reports
    assert tra.max_ratio(calibrated=True) <= tra.max_ratio(False)
    assert tra.max_ratio(calibrated=True) <= 1.6


def test_calibration_in_options_and_cache_key(chip):
    from repro.explore import ExplorationEngine, mg_flit_space
    eng = ExplorationEngine("tiny_cnn", params=CostParams(batch=2),
                            cache=None)
    space = mg_flit_space((4, 8), (8,), strategies=("dp",))
    pt = space.points()[0]
    k_raw = eng._key(pt, "analytic")
    eng.calibration = Calibration(vector=5.0)
    assert eng._key(pt, "analytic") != k_raw
    # the simulator is calibration-free: its key must not move
    eng2 = ExplorationEngine("tiny_cnn", params=CostParams(batch=2),
                             cache=None)
    assert eng._key(pt, "simulate") == eng2._key(pt, "simulate")


def test_calibrated_evaluation_applies_factors(chip):
    opts = CompileOptions(strategy="dp", params=CostParams(batch=4))
    art = flow.compile("tiny_cnn", chip, opts)
    base = art.evaluate("analytic").cycles
    cal = art.replace_options(
        calibration=Calibration(makespan=3.0)).evaluate("analytic")
    assert cal.cycles == pytest.approx(3.0 * base)
    tr_base = art.evaluate("trace").cycles
    tr_cal = art.replace_options(
        calibration=Calibration(makespan=3.0)).evaluate("trace")
    assert tr_cal.cycles == pytest.approx(3.0 * tr_base)


# ---------------------------------------------------------------------------
# The fig6 gap test: calibrated screening ranks like the simulator
# ---------------------------------------------------------------------------


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_fig6_calibrated_rank_matches_simulator():
    from repro.explore import ExplorationEngine, by_edp, mg_flit_space
    from repro.explore.space import SWEEP_FLIT, SWEEP_MG

    space = mg_flit_space(SWEEP_MG, SWEEP_FLIT, strategies=("generic",))
    pts = space.points()
    eng = ExplorationEngine("resnet18", res=112,
                            params=CostParams(batch=4), cache=None)

    def top3(recs):
        ranked = sorted(recs, key=by_edp)[:3]
        return {(r.point.macros_per_group, r.point.flit_bytes)
                for r in ranked}

    raw = eng.evaluate(pts, fidelity="analytic")
    sim = eng.evaluate(pts, fidelity="simulate")
    # fit on the raw screen's best point (one extra simulator run)
    eng.calibrate([sorted(raw, key=by_edp)[0].point], max_points=1)
    cal = eng.evaluate(pts, fidelity="analytic")

    assert top3(cal) == top3(sim), (
        f"calibrated analytic top-3 {top3(cal)} != simulator top-3 "
        f"{top3(sim)} (raw was {top3(raw)})")
    # calibrated absolute cycles track the simulator per point
    for c, s in zip(cal, sim):
        assert c.cycles == pytest.approx(s.cycles, rel=0.25), c.point


# ---------------------------------------------------------------------------
# Batched evaluation + persistent pass cache
# ---------------------------------------------------------------------------


def test_compile_many_matches_compile(chip):
    small = default_chip(macros_per_group=4)
    pipe = flow.Pipeline()
    opts = CompileOptions(strategy="dp", params=CostParams(batch=2))
    arts = pipe.compile_many("tiny_cnn", [chip, small], opts)
    singles = [flow.compile("tiny_cnn", c, opts) for c in (chip, small)]
    for a, b in zip(arts, singles):
        assert a.evaluate("analytic").cycles \
            == pytest.approx(b.evaluate("analytic").cycles)
    # one condense for the whole batch
    info = pipe.cache_info()
    assert info["misses"] == 3          # 1 condense + 2 partitions


def test_disk_pass_cache_shared_across_pipelines(tmp_path, chip):
    cache_dir = str(tmp_path / "flowcache")
    opts = CompileOptions(strategy="dp", params=CostParams(batch=2))
    p1 = flow.Pipeline(disk_cache=cache_dir)
    p1.compile("tiny_cnn", chip, opts)
    assert len(p1.disk) >= 2            # condense + partition persisted
    # a fresh pipeline (fresh process stand-in) hits the disk tier
    p2 = flow.Pipeline(disk_cache=cache_dir)
    art = p2.compile("tiny_cnn", chip, opts)
    assert all(rec.cached for rec in art.trace), art.describe()
    assert p2.disk.hits >= 2
    assert p2.disk.clear() >= 2


def test_pass_disk_cache_prune(tmp_path):
    from repro.flow import PassDiskCache
    import os
    c = PassDiskCache(str(tmp_path / "pc"))
    for i in range(4):
        key = f"{i:02d}" + "a" * 62
        c.put(key, {"i": i})
        os.utime(c._path(key), (i * 1000.0, i * 1000.0))
    assert len(c) == 4
    assert c.prune(max_entries=2) == 2
    assert len(c) == 2
    # the newest entries survive
    ok, out = c.get("03" + "a" * 62)
    assert ok and out == {"i": 3}
    assert c.prune(max_age_days=1.0, now=3000.0 + 2 * 86400.0) == 2
    assert len(c) == 0


def test_engine_calibrate_seeds_simulator_cache(tmp_path):
    from repro.explore import ExplorationEngine, mg_flit_space
    eng = ExplorationEngine("tiny_cnn", params=CostParams(batch=2),
                            cache=str(tmp_path / "res"))
    pt = mg_flit_space((8,), (8,), strategies=("dp",)).points()[0]
    eng.calibrate([pt], max_points=1)
    # the fit's ground-truth run must serve the later promotion
    rec = eng.evaluate([pt], fidelity="simulate")[0]
    assert rec.cache_hit and rec.ok


def test_engine_trace_fidelity_and_halving(tmp_path, monkeypatch):
    from repro.explore import (ExplorationEngine, mg_flit_space,
                               successive_halving)
    from repro.flow.diskcache import ENV_VAR

    # ExplorationEngine(flow_cache=...) deliberately binds the
    # process-wide default pipeline (and env) to the cache dir so pool
    # workers inherit it; restore both after the test
    pipe = flow.default_pipeline()
    monkeypatch.delenv(ENV_VAR, raising=False)
    prev_disk = pipe.disk
    try:
        eng = ExplorationEngine("tiny_cnn", params=CostParams(batch=2),
                                cache=str(tmp_path / "results"),
                                flow_cache=str(tmp_path / "passes"))
        space = mg_flit_space((4, 8), (8,), strategies=("dp",))
        recs = eng.evaluate(space.points(), fidelity="trace")
        assert all(r.ok and r.fidelity == "trace" for r in recs)
        # calibrated successive halving end-to-end (fits on 1 sim run)
        res, screened = successive_halving(eng, space, top_k=1,
                                           calibrate=1)
        assert res.best.fidelity == "simulate"
        assert eng.calibration is not None
        assert len(screened) == len(space.points())
        assert pipe.disk is not None and len(pipe.disk) > 0
    finally:
        pipe.disk = prev_disk
        monkeypatch.delenv(ENV_VAR, raising=False)


# ---------------------------------------------------------------------------
# named calibration presets (flow.calibrate(..., save=...) round trip)
# ---------------------------------------------------------------------------


def test_calibration_preset_roundtrip(tmp_path, monkeypatch, chip):
    from repro.flow import (list_calibrations, load_calibration,
                            save_calibration)
    monkeypatch.setenv("REPRO_CALIB_DIR", str(tmp_path))
    calib = Calibration(cim=1.5, vector=2.0, makespan=1.1)
    path = save_calibration(calib, "unit-test",
                            meta={"chip": chip.name})
    assert path.endswith("unit-test.json")
    assert list_calibrations() == ["unit-test"]
    assert load_calibration("unit-test") == calib
    # CompileOptions resolves the name at construction time
    opts = CompileOptions(params=CostParams(batch=2),
                          calibration="unit-test")
    assert opts.calibration == calib
    # and the engine accepts the name too
    from repro.explore import ExplorationEngine
    eng = ExplorationEngine("tiny_cnn", params=CostParams(batch=2),
                            calibration="unit-test")
    assert eng.calibration == calib
    with pytest.raises(FileNotFoundError, match="no calibration preset"):
        load_calibration("missing-preset")


def test_calibrate_save_writes_preset(tmp_path, monkeypatch, chip):
    monkeypatch.setenv("REPRO_CALIB_DIR", str(tmp_path))
    rep = flow.calibrate(["tiny_cnn"], chip,
                         params=CostParams(batch=2), save="tiny-fit")
    got = flow.load_calibration("tiny-fit")
    assert got == rep.calibration
    import json
    with open(tmp_path / "tiny-fit.json") as f:
        doc = json.load(f)
    assert doc["fidelity"] == "analytic"
    assert doc["workloads"] == ["tiny_cnn"]


# ---------------------------------------------------------------------------
# transformer: dynamic-weight workload on the full fidelity ladder
# ---------------------------------------------------------------------------

TRANSFORMER_KW = {"n_layers": 1, "d_model": 128, "n_heads": 4,
                  "seq": 16, "vocab": 64}


def test_trace_transformer_smoke(chip):
    """The trace fidelity replays dynamic-weight attention without
    codegen — pin sane, ladder-ordered costs and the no-lowering
    contract."""
    opts = CompileOptions(
        params=CostParams(batch=2),
        workload_kw={"n_layers": 1, "d_model": 128, "n_heads": 4,
                     "seq": 16})
    art = flow.compile("transformer", chip, opts)
    ana = art.evaluate("analytic")
    tr = art.evaluate("trace")
    assert tr.backend == "trace"
    assert tr.trace is not None and tr.trace.n_events > 0
    assert tr.cycles > 0 and tr.energy_total > 0
    # no codegen: the replay never lowered to ISA programs
    assert art.model is None
    # ladder ordering: trace adds serialization the analytic model
    # idealizes away
    assert tr.cycles >= ana.cycles


def test_transformer_full_fidelity_ladder(chip):
    """ISSUE 5 acceptance: the transformer compiles and evaluates under
    analytic / trace / simulate on the default chip — no OpLevelError /
    CodegenError — with vectorsim cycles bit-identical to the scalar
    interpreter (func-mode bit-exactness is pinned against the JAX
    reference in test_compile_run)."""
    opts = CompileOptions(params=CostParams(batch=2),
                          workload_kw=TRANSFORMER_KW)
    art = flow.compile("transformer", chip, opts)
    ana = art.evaluate("analytic")
    tr = art.evaluate("trace")
    vec = art.evaluate("simulate", engine="vector")
    scal = art.evaluate("simulate", engine="scalar")
    assert vec.cycles == scal.cycles > 0
    assert 0 < ana.cycles <= tr.cycles * 1.001
    # the weight-source trace model tracks the simulator closely on
    # attention (the old ad-hoc prologue model could not price it)
    assert 0.5 <= tr.cycles / vec.cycles <= 2.0


def test_calibration_transfers_across_model_families(chip, calib_reports):
    """ROADMAP: measure how well calibration factors transfer across
    model families — factors fit on CNNs (tiny_cnn + resnet18@112)
    applied to transformers must preserve the simulator's *ranking* of
    transformer variants, and calibrated trace must stay within the
    documented 2x band."""
    ana_rep, tra_rep = calib_reports
    variants = [
        TRANSFORMER_KW,
        {"n_layers": 2, "d_model": 64, "n_heads": 2, "seq": 24,
         "vocab": 48},
        {"n_layers": 1, "d_model": 256, "n_heads": 8, "seq": 8,
         "vocab": 64},
    ]
    rows = []
    for kw in variants:
        art = flow.compile("transformer", chip,
                           CompileOptions(params=CostParams(batch=2),
                                          workload_kw=kw))
        sim = art.evaluate("simulate").cycles
        cal_ana = art.replace_options(
            calibration=ana_rep.calibration).evaluate("analytic").cycles
        cal_tr = art.replace_options(
            calibration=tra_rep.calibration).evaluate("trace").cycles
        rows.append((sim, cal_ana, cal_tr))

    def rank(idx):
        return sorted(range(len(rows)), key=lambda i: rows[i][idx])

    # ranking fidelity transfers for both calibrated screens
    assert rank(1) == rank(0), "CNN-calibrated analytic mis-ranks"
    assert rank(2) == rank(0), "CNN-calibrated trace mis-ranks"
    # absolute transfer: calibrated trace stays within the 2x band
    for sim, _, cal_tr in rows:
        assert 0.5 <= cal_tr / sim <= 2.0


def test_committed_default_presets_resolve(monkeypatch, tmp_path):
    # the repo ships default-chip presets; the default directory is
    # anchored to the repo root, so they must load from any CWD
    monkeypatch.delenv("REPRO_CALIB_DIR", raising=False)
    monkeypatch.chdir(tmp_path)
    for name in ("default-chip-analytic", "default-chip-trace"):
        c = flow.load_calibration(name)
        assert c.makespan > 0
    assert "default-chip-trace" in flow.list_calibrations()

"""End-to-end: graph -> partition -> codegen -> functional ISS == oracle.

These are the paper-system behaviour tests: compiled CIMFlow instruction
streams executed by the functional simulator must be bit-exact against the
pure-numpy INT8 oracle, across single-core, multi-core (n-split assembly),
duplicated (weight replication) and multi-round (weight streaming) mappings.
"""

import numpy as np
import pytest

from repro.core import ref, workloads
from repro.core.arch import default_chip
from repro.core.codegen import CompiledModel, QuantParams, compile_model
from repro.core.graph import Graph
from repro.core.mapping import CostParams
from repro.core.partition import partition
from repro.core.simulator import Simulator

RNG = np.random.default_rng(0)


def _weights_for(cg):
    """Random int8 weights/biases in the (K_total, N_total) matrix layout."""
    src = cg.source
    weights, biases = {}, {}
    for g in cg:
        if g.anchor is None:
            continue
        op = src.ops[g.anchor]
        lo, hi = -6, 7
        if op.kind == "conv":
            k = op.attrs["k"]
            cin = src.ops[op.inputs[0]].out_shape[-1]
            ker = RNG.integers(lo, hi, (k, k, cin, op.gemm_n),
                               dtype=np.int8)
            weights[g.idx] = ref.conv_weight_matrix(ker)
        elif op.kind == "dwconv":
            k = op.attrs["k"]
            c = op.groups
            ker = RNG.integers(lo, hi, (k, k, c), dtype=np.int8)
            weights[g.idx] = ref.dwconv_weight_matrix(ker)
        elif op.kind == "linear":
            weights[g.idx] = RNG.integers(lo, hi, (g.gemm_k, g.gemm_n),
                                          dtype=np.int8)
        if "bias" in ref._vops(cg, g):
            biases[g.idx] = RNG.integers(-40, 40, g.gemm_n
                                         * (g.groups if g.groups > 1
                                            else 1)).astype(np.int32)
    return weights, biases


def _run_both(graph: Graph, chip, batch=2, strategy="dp", params=None):
    cg = graph.condense()
    res = partition(cg, chip, strategy,
                    params or CostParams(batch=batch))
    weights, biases = _weights_for(cg)
    inputs = RNG.integers(-8, 8, (batch,) + cg.source.ops[0].out_shape
                          ).astype(np.int8)
    qp = ref.auto_quant(cg, weights, biases, inputs)
    model = compile_model(res, batch=batch, quant=qp, strict_lmem=True)
    img = model.build_gmem_image(weights, biases, inputs)
    sim = Simulator(chip, model.isa, mode="func")
    rep = sim.run_model(model, gmem_image=img)
    oracle = ref.run_reference(cg, weights, biases, qp, inputs)
    return model, rep, oracle, cg


def _check_final(model: CompiledModel, rep, oracle, cg, batch=2):
    last = len(cg) - 1
    for s in range(batch):
        addr, nb = model.output_addr(last, s)
        got = rep.gmem[addr - 0x10000000: addr - 0x10000000 + nb]
        want = oracle[last][s].reshape(-1)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"sample {s} mismatch")


# ---------------------------------------------------------------------------


def test_single_linear_layer():
    g = Graph("lin")
    x = g.input("x", (64,))
    g.linear("fc", x, cout=32, act="relu")
    chip = default_chip(n_cores=1, mesh_cols=1)
    model, rep, oracle, cg = _run_both(g, chip)
    _check_final(model, rep, oracle, cg)
    assert rep.cycles > 0 and rep.instrs > 0


def test_linear_multicore_nsplit():
    """N=256 on a 2-MG chip forces n-tile columns across 2+ cores
    (assembly-core gather path)."""
    g = Graph("lin2")
    x = g.input("x", (256,))
    g.linear("fc1", x, cout=256, act="relu")
    g2 = g.linear("fc2", len(g.ops) - 1, cout=16)
    chip = default_chip(n_cores=4, mesh_cols=2, n_macro_groups=2,
                        macros_per_group=2)
    model, rep, oracle, cg = _run_both(g, chip)
    _check_final(model, rep, oracle, cg)
    # verify the n-split actually happened
    sched = model.stages[0].schedules[0]
    assert len(sched.replicas[0].cores) >= 2


def test_linear_multiround_streaming():
    """K=4096 on a tiny CIM unit exceeds slots -> weight-streaming rounds."""
    g = Graph("big_k")
    x = g.input("x", (4096,))
    g.linear("fc", x, cout=8)
    chip = default_chip(n_cores=1, mesh_cols=1, n_macro_groups=4,
                        macros_per_group=1)
    model, rep, oracle, cg = _run_both(g, chip, batch=1)
    sched = model.stages[0].schedules[0]
    assert sched.n_rounds > 1
    _check_final(model, rep, oracle, cg, batch=1)


def test_tiny_cnn_end_to_end():
    """conv -> maxpool -> conv -> GAP -> fc across multiple cores."""
    g = workloads.tiny_cnn(res=8, c=8)
    chip = default_chip(n_cores=8, mesh_cols=4)
    model, rep, oracle, cg = _run_both(g, chip)
    _check_final(model, rep, oracle, cg)


def test_residual_block_skip_add():
    g = Graph("res")
    x = g.input("x", (8, 8, 8))
    c1 = g.conv("c1", x, cout=8, k=3, act="relu", use_bn=False)
    c2 = g.conv("c2", c1, cout=8, k=3, use_bn=False)
    a = g.eltwise("add", "add", c2, c1)
    r = g.unary("relu", "relu", a)
    g.linear("fc", g.globalpool("gap", r), cout=4)
    chip = default_chip(n_cores=8, mesh_cols=4)
    model, rep, oracle, cg = _run_both(g, chip)
    _check_final(model, rep, oracle, cg)


def test_depthwise_conv():
    g = Graph("dw")
    x = g.input("x", (8, 8, 16))
    d = g.conv("dw", x, cout=16, k=3, groups=16, act="relu", use_bn=False)
    g.linear("fc", g.globalpool("gap", d), cout=4)
    chip = default_chip(n_cores=4, mesh_cols=2)
    model, rep, oracle, cg = _run_both(g, chip)
    _check_final(model, rep, oracle, cg)


def test_strided_conv_with_padding():
    g = Graph("stride")
    x = g.input("x", (9, 9, 4))
    c = g.conv("c", x, cout=8, k=3, stride=2, act="relu", use_bn=False)
    g.linear("fc", g.globalpool("gap", c), cout=4)
    chip = default_chip(n_cores=4, mesh_cols=2)
    model, rep, oracle, cg = _run_both(g, chip)
    _check_final(model, rep, oracle, cg)


def test_duplication_correctness():
    """Plenty of cores -> optimal mapping duplicates; results unchanged."""
    g = Graph("dup")
    x = g.input("x", (12, 12, 4))
    c1 = g.conv("c1", x, cout=8, k=3, act="relu", use_bn=False)
    c2 = g.conv("c2", c1, cout=8, k=3, act="relu", use_bn=False)
    g.linear("fc", g.globalpool("gap", c2), cout=4)
    chip = default_chip(n_cores=16, mesh_cols=4)
    model, rep, oracle, cg = _run_both(g, chip, batch=2)
    dups = [s.alloc.dup for st in model.stages for s in st.schedules]
    assert max(dups) > 1, "expected weight duplication to kick in"
    _check_final(model, rep, oracle, cg)


def test_maxpool_with_padding():
    g = Graph("poolpad")
    x = g.input("x", (8, 8, 4))
    c = g.conv("c", x, cout=8, k=3, act="relu", use_bn=False)
    p = g.pool("p", c, k=3, stride=2, padding=1)
    g.linear("fc", g.globalpool("gap", p), cout=4)
    chip = default_chip(n_cores=4, mesh_cols=2)
    model, rep, oracle, cg = _run_both(g, chip)
    _check_final(model, rep, oracle, cg)


def test_streamed_group_shares_stage():
    """A weight-streaming (multi-round) group no longer monopolizes its
    stage: it co-schedules with its producer on disjoint core windows,
    pipelines within the stage, and stays bit-exact."""
    g = Graph("stream_shared")
    x = g.input("x", (32, 32, 4))
    c = g.conv("c1", x, cout=4, k=3, act="relu", use_bn=False)
    f = g.unary("flatten", "flatten", c)
    h, w, cc = g.ops[c].out_shape
    g.ops[f].out_shape = (h * w * cc,)
    g.linear("fc", f, cout=8)
    chip = default_chip(n_cores=2, mesh_cols=1, n_macro_groups=4,
                        macros_per_group=1)
    model, rep, oracle, cg = _run_both(g, chip, batch=2)
    assert len(model.stages) == 1, "streaming group not co-scheduled"
    by_src = {sc.weight_source: sc for st in model.stages
              for sc in st.schedules}
    assert by_src["streamed"].n_rounds > 1
    assert "static" in by_src
    _check_final(model, rep, oracle, cg)


def test_transformer_dynamic_weights_end_to_end():
    """Dynamic-weight attention (Q·Kᵀ / P·V written into macro groups
    from RECV'd activations) + fused softmax/layernorm/gelu tails: the
    compiled streams must match the oracle bit-exactly on the default
    chip, through the weight-source lowering path."""
    g = workloads.transformer_lm(n_layers=1, d_model=128, n_heads=4,
                                 seq=16, vocab=64)
    chip = default_chip()
    model, rep, oracle, cg = _run_both(g, chip, batch=2)
    _check_final(model, rep, oracle, cg)
    sources = {sc.weight_source for st in model.stages
               for sc in st.schedules}
    assert "dynamic" in sources, "attention did not lower dynamically"


def test_transformer_dynamic_multiround_end_to_end():
    """A slot-starved chip forces the dynamic path through multi-round
    streaming with multiple m-chunks — the restriction the static path
    still has — and must stay bit-exact."""
    g = workloads.transformer_lm(n_layers=1, d_model=128, n_heads=4,
                                 seq=16, vocab=64)
    chip = default_chip(n_cores=2, mesh_cols=1, n_macro_groups=2,
                        macros_per_group=2)
    model, rep, oracle, cg = _run_both(g, chip, batch=2)
    _check_final(model, rep, oracle, cg)
    dyn_rounds = max(sc.n_rounds for st in model.stages
                     for sc in st.schedules
                     if sc.weight_source == "dynamic")
    assert dyn_rounds > 1, "expected multi-round dynamic streaming"


def test_transformer_func_matches_jax_reference():
    """Acceptance: func-mode output == the JAX reference.

    The reference is an *independent* jnp forward pass — per-head
    einsum attention instead of block-diagonal matrices, the shared
    integer softmax/layernorm/gelu semantics re-implemented in jnp —
    checked against the functional ISS output of the compiled model.
    """
    jax = pytest.importorskip("jax")
    from jax.experimental import enable_x64
    import jax.numpy as jnp
    from repro.core import vecsem

    H, dh, seq, d, vocab = 2, 32, 8, 64, 32
    g = workloads.transformer_lm(n_layers=1, d_model=d, n_heads=H,
                                 seq=seq, vocab=vocab)
    cg = g.condense()
    chip = default_chip(n_cores=8, mesh_cols=4)
    res = partition(cg, chip, "dp", CostParams(batch=2))
    weights, biases = _weights_for(cg)
    inputs = RNG.integers(-8, 8, (2, seq, d)).astype(np.int8)
    qp = ref.auto_quant(cg, weights, biases, inputs)
    model = compile_model(res, batch=2, quant=qp, strict_lmem=True)
    img = model.build_gmem_image(weights, biases, inputs)
    rep = Simulator(chip, model.isa, mode="func").run_model(
        model, gmem_image=img)

    gid = {grp.name: grp.idx for grp in cg}
    with enable_x64():
        EXP2 = jnp.asarray(vecsem.EXP2_LUT)
        GELU = jnp.asarray(vecsem.GELU_LUT)

        def j_quant(acc, gd):
            q = qp[gd]
            den = 1 << q.shift
            v = (acc.astype(jnp.int64) * q.scale + (den >> 1)) // den
            return jnp.clip(v, -128, 127).astype(jnp.int8)

        def j_lin(x, gd):
            w = jnp.asarray(weights[gd], jnp.int32)
            return j_quant(x.astype(jnp.int32) @ w, gd)

        def j_softmax(x):
            xi = x.astype(jnp.int64)
            dd = jnp.clip(xi.max(-1, keepdims=True) - xi, 0, 255)
            e = EXP2[dd]
            s = e.sum(-1, keepdims=True)
            return jnp.clip((127 * e + (s >> 1)) // s, 0,
                            127).astype(jnp.int8)

        def j_layernorm(x):
            xi = x.astype(jnp.int64)
            n = x.shape[-1]
            s = xi.sum(-1, keepdims=True)
            dv = n * xi - s
            ss = (dv * dv).sum(-1, keepdims=True)
            r = jnp.sqrt((ss // n).astype(jnp.float64)).astype(jnp.int64)
            r = jnp.where(r * r > ss // n, r - 1, r)
            r = jnp.where((r + 1) * (r + 1) <= ss // n, r + 1, r) + 1
            y = (2 * vecsem.LN_GAIN * dv + r) // (2 * r)
            return jnp.clip(y, -128, 127).astype(jnp.int8)

        def j_sat_add(a, b):
            return jnp.clip(a.astype(jnp.int16) + b.astype(jnp.int16),
                            -128, 127).astype(jnp.int8)

        def heads(x):                     # (seq, d) -> (H, seq, dh)
            return x.reshape(seq, H, dh).transpose(1, 0, 2)

        outs = []
        for s in range(2):
            x = jnp.asarray(inputs[s])
            e_ = j_lin(x, gid["embed"])
            qv = heads(j_lin(e_, gid["l0.attn.q"])).astype(jnp.int32)
            kv = heads(j_lin(e_, gid["l0.attn.k"])).astype(jnp.int32)
            vv = heads(j_lin(e_, gid["l0.attn.v"])).astype(jnp.int32)
            sc = j_quant(jnp.einsum("hmd,hnd->hmn", qv, kv),
                         gid["l0.attn.scores"])
            sm = j_softmax(sc).astype(jnp.int32)
            ctx = j_quant(jnp.einsum("hmn,hnd->hmd", sm, vv),
                          gid["l0.attn.ctx"])
            ctx = ctx.transpose(1, 0, 2).reshape(seq, d)
            o = j_lin(ctx, gid["l0.attn.o"])
            x1 = j_layernorm(j_sat_add(o, e_))
            up = GELU[j_lin(x1, gid["l0.up"]).astype(jnp.int16) + 128]
            dn = j_lin(up, gid["l0.down"])
            x2 = j_layernorm(j_sat_add(dn, x1))
            outs.append(np.asarray(j_lin(x2, gid["lm_head"])))

    last = len(cg) - 1
    for s in range(2):
        addr, nb = model.output_addr(last, s)
        got = rep.gmem[addr - 0x10000000: addr - 0x10000000 + nb]
        np.testing.assert_array_equal(
            got, outs[s].reshape(-1),
            err_msg=f"func-mode output != JAX reference (sample {s})")


def test_perf_mode_matches_func_timing():
    """perf mode (no data) must report identical cycle counts."""
    g = workloads.tiny_cnn(res=8, c=8)
    cg = g.condense()
    chip = default_chip(n_cores=8, mesh_cols=4)
    res = partition(cg, chip, "dp", CostParams(batch=2))
    weights, biases = _weights_for(cg)
    inputs = RNG.integers(-8, 8, (2, 8, 8, 3)).astype(np.int8)
    qp = ref.auto_quant(cg, weights, biases, inputs)
    model = compile_model(res, batch=2, quant=qp, strict_lmem=True)
    img = model.build_gmem_image(weights, biases, inputs)
    f = Simulator(chip, model.isa, mode="func").run_model(model, img)
    p = Simulator(chip, model.isa, mode="perf").run_model(model)
    assert f.cycles == p.cycles
    assert f.events["cim_macro_passes"] == p.events["cim_macro_passes"]

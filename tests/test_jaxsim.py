"""Equivalence suite: jitted JAX stage engine vs the scalar interpreter.

The JAX engine (:mod:`repro.core.jaxsim`) must be *bit-identical* to
the scalar interpreter and the numpy vector engine — same cycles, same
stage makespans, same energy-event ledger, same per-unit busy totals,
same executed-instruction count.  This suite pins that contract on the
golden compiled workloads, hand-built corner cases and
hypothesis-randomized programs; it also pins the fleet contract (a
vmapped multi-machine decode equals a loop of single-machine runs over
the same compiled model), the ``ExplorationEngine(engine="jax")``
routing/caching behaviour, and the ``func:pallas`` oracle backend.
"""

import dataclasses
import os

import numpy as np
import pytest

from repro import flow
from repro.core import jaxsim, vectorsim
from repro.core.arch import default_chip
from repro.core.codegen import StageProgram, _ensure_vec_flag_operand
from repro.core.isa import Program, SREG, default_isa
from repro.core.machine import machine_for
from repro.core.mapping import CostParams
from repro.core.simulator import ENGINES, Simulator
from repro.explore import (ExplorationEngine, FleetEvaluator,
                           canonical_chip, timing_space)
from repro.explore.records import EvalRecord

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.filterwarnings(
    "ignore:perf-mode lmem overflow:RuntimeWarning")

CHIP = default_chip()
ISA = default_isa()
_ensure_vec_flag_operand(ISA)


# ---------------------------------------------------------------------------
# helpers (mirrors test_vectorsim)
# ---------------------------------------------------------------------------


def run_stage_both(programs, chip=CHIP):
    sp = StageProgram(stage=None, schedules=[], programs=programs)
    out_s = Simulator(chip, ISA, engine="scalar")._run_stage(sp, None)
    out_j = jaxsim.run_stage(Simulator(chip, ISA, engine="jax"), sp)
    assert out_j is not None, "stage unexpectedly not decodable"
    return out_s, out_j


def assert_identical(out_s, out_j):
    makespan_s, events_s, busy_s, instrs_s = out_s
    makespan_j, events_j, busy_j, instrs_j = out_j
    assert makespan_j == makespan_s
    assert events_j == events_s
    assert busy_j == busy_s
    assert instrs_j == instrs_s


def assert_reports_identical(a, b):
    assert a.cycles == b.cycles
    assert a.stage_cycles == b.stage_cycles
    assert a.events == b.events
    assert a.unit_busy == b.unit_busy
    assert a.instrs == b.instrs


def prog(core_id, *instrs):
    p = Program(core_id=core_id)
    for op, args in instrs:
        p.append(ISA.instr(op, **args))
    return p


def I(op, **args):                       # noqa: E743 — terse test DSL
    return (op, args)


def _send(core, dst, size, stream, value_reg_base=1):
    r = value_reg_base
    return [
        I("CIM_CFG", sreg=SREG["CHANNEL"], imm=stream),
        I("S_ADDI", dst=r, a=0, imm=dst),
        I("S_ADDI", dst=r + 1, a=0, imm=64),
        I("S_ADDI", dst=r + 2, a=0, imm=size),
        I("SEND", core=r, src=r + 1, size=r + 2),
    ]


def _recv(core, src, size, stream, value_reg_base=4):
    r = value_reg_base
    return [
        I("CIM_CFG", sreg=SREG["CHANNEL"], imm=stream),
        I("S_ADDI", dst=r, a=0, imm=128),
        I("S_ADDI", dst=r + 1, a=0, imm=src),
        I("S_ADDI", dst=r + 2, a=0, imm=size),
        I("RECV", dst=r, core=r + 1, size=r + 2),
    ]


def _timing_chips(n=6):
    """Chips sharing CHIP's structure, varying only timing constants."""
    chips = []
    for i in range(n):
        chips.append(dataclasses.replace(
            CHIP,
            core=dataclasses.replace(
                CHIP.core,
                scalar=dataclasses.replace(CHIP.core.scalar,
                                           alu_latency=1 + i % 3,
                                           ldst_latency=2 + i % 2),
                vector=dataclasses.replace(CHIP.core.vector,
                                           alu_latency=1 + i % 4,
                                           mul_latency=2 + i % 3),
                cim=dataclasses.replace(
                    CHIP.core.cim,
                    weight_load_rows_per_cycle=1 + i % 4)),
            noc=dataclasses.replace(CHIP.noc,
                                    router_latency=1 + i % 3),
            clock_ghz=1.0 + 0.2 * i,
            name=f"t{i}"))
    return chips


# ---------------------------------------------------------------------------
# golden compiled workloads: jax == scalar
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model,kw,strategy", [
    ("tiny_cnn", {}, "dp"),
    ("tiny_cnn", {}, "generic"),
    ("resnet18", {"res": 64}, "dp"),
    ("transformer", {"n_layers": 1, "d_model": 128, "n_heads": 4,
                     "seq": 16, "vocab": 64}, "dp"),
])
def test_golden_workload_equivalence(model, kw, strategy):
    art = flow.compile(model, CHIP,
                       flow.CompileOptions(strategy=strategy,
                                           params=CostParams(batch=2),
                                           workload_kw=kw or None))
    cm = art.ensure_model()
    scal = Simulator(CHIP, cm.isa, engine="scalar").run_model(cm)
    jx = Simulator(CHIP, cm.isa, engine="jax").run_model(cm)
    assert_reports_identical(scal, jx)


# ---------------------------------------------------------------------------
# hand-built corner cases
# ---------------------------------------------------------------------------


def test_recv_blocks_until_send():
    p0 = prog(0, *(_send(0, 1, 32, 7)
                   + [I("S_ADDI", dst=5, a=0, imm=1)] * 50
                   + [I("HALT", )]))
    p1 = prog(1, *(_recv(1, 0, 32, 7) + [I("HALT",)]))
    assert_identical(*run_stage_both({0: p0, 1: p1}))


def test_sync_barrier_and_gmem_ports():
    def core_prog(cid, delay):
        body = [I("S_ADDI", dst=1, a=0, imm=256),
                I("S_ADDI", dst=2, a=0, imm=1024 * cid),
                I("S_ADDI", dst=3, a=0, imm=200 + delay)]
        body += [I("NOP",)] * delay
        body += [I("GLD", dst=1, gaddr=2, size=3)]
        body += [I("SYNC", barrier=1)]
        body += [I("GST", src=1, gaddr=2, size=3)]
        body += [I("HALT",)]
        return prog(cid, *body)

    programs = {c: core_prog(c, 3 * c) for c in range(5)}
    assert_identical(*run_stage_both(programs))


def test_cfgr_and_lui_addi_chains():
    p = prog(0,
             I("S_LUI", dst=9, imm=2),
             I("S_ADDI", dst=9, a=9, imm=100),
             I("CIM_CFGR", sreg=SREG["VLEN"], src=9),
             I("V_ADD", dst=1, a=2, b=3),
             I("S_LD", dst=9, base=1, off=0),
             I("CIM_CFGR", sreg=SREG["VLEN"], src=9),
             I("V_ADD", dst=1, a=2, b=3),
             I("HALT",))
    assert_identical(*run_stage_both({0: p}))


def test_mvm_occupancy_and_vector_classes():
    p = prog(0,
             I("CIM_CFG", sreg=SREG["MG_NLEN"], imm=16),
             I("CIM_CFG", sreg=SREG["MG_KOFF"], imm=0),
             I("S_ADDI", dst=1, a=0, imm=0),
             I("CIM_LOAD", mg=0, src=1, rows=64),
             I("CIM_LOAD", mg=2, src=1, rows=32),
             I("CIM_CFG", sreg=SREG["MG_MASK_LO"], imm=0b101),
             I("CIM_CFG", sreg=SREG["MVM_SEG_IN"], imm=64),
             I("CIM_CFG", sreg=SREG["MVM_SEG_OUT"], imm=128),
             I("CIM_MVM", dst=1, src=1, rep=7, acc=0),
             I("V_SETVL", len=48),
             I("CIM_CFG", sreg=SREG["V_REP"], imm=3),
             I("V_MUL", dst=1, a=2, b=3),
             I("V_SIGMOID", dst=1, a=2, b=0),
             I("V_MAX", dst=1, a=2, b=3, flags=4),
             I("HALT",))
    assert_identical(*run_stage_both({0: p}))


def test_branchy_program_unrolls_statically():
    body = [I("S_ADDI", dst=1, a=0, imm=3),
            I("S_ADDI", dst=2, a=0, imm=0),
            I("S_ADDI", dst=1, a=1, imm=-1),
            I("BNE", a=1, b=2, off=-1),
            I("HALT",)]
    assert_identical(*run_stage_both({0: prog(0, *body)}))


def test_nonpow2_timing_constants_identical():
    """Non-dyadic latencies (1/3-cycle weight-load rows, 3-flit links)
    through the device latency mirrors: still bit-identical, because the
    host replays the device's per-instruction float64 latencies through
    the same summation order as the interpreter."""
    base = default_chip(n_cores=8, mesh_cols=4)
    chip = dataclasses.replace(
        base,
        core=dataclasses.replace(
            base.core,
            cim=dataclasses.replace(base.core.cim,
                                    weight_load_rows_per_cycle=3)),
        noc=dataclasses.replace(base.noc, flits_per_cycle=3),
        global_mem_bytes_per_cycle=48,
        name="nonpow2")
    art = flow.compile("tiny_cnn", chip,
                       flow.CompileOptions(params=CostParams(batch=2)))
    cm = art.ensure_model()
    scal = Simulator(chip, cm.isa, engine="scalar").run_model(cm)
    jx = Simulator(chip, cm.isa, engine="jax").run_model(cm)
    assert jx.cycles == scal.cycles
    assert jx.stage_cycles == scal.stage_cycles
    assert jx.events == scal.events
    assert jx.instrs == scal.instrs
    for unit, b in scal.unit_busy.items():
        assert jx.unit_busy[unit] == pytest.approx(b, rel=1e-12)


def test_engine_validation():
    assert "jax" in ENGINES
    with pytest.raises(ValueError):
        Simulator(CHIP, ISA, mode="func", engine="jax")
    with pytest.raises(ValueError):
        ExplorationEngine("tiny_cnn", engine="warp")


# ---------------------------------------------------------------------------
# fleet: vmapped multi-machine decode == loop of single runs
# ---------------------------------------------------------------------------


def test_canonical_chip_groups_timing_variants():
    chips = _timing_chips(4)
    canons = {canonical_chip(c) for c in chips}
    assert len(canons) == 1              # timing fields reset away
    assert canonical_chip(CHIP) in canons
    structural = dataclasses.replace(CHIP, n_cores=16, mesh_cols=4)
    assert canonical_chip(structural) != canonical_chip(CHIP)


def test_fleet_equals_loop_of_single_evals():
    """The satellite contract: one vmapped fleet evaluation is
    bit-identical (cycles, events-priced energy, throughput) to a loop
    of single-machine ``engine="jax"`` runs over the same compiled
    model."""
    chips = _timing_chips(6)
    cg = flow.compile("tiny_cnn", CHIP,
                      flow.CompileOptions(params=CostParams(batch=2))).cg
    fe = FleetEvaluator(cg, params=CostParams(batch=2))
    payloads = fe.evaluate([(c, "dp") for c in chips])
    art = flow.compile(cg, canonical_chip(chips[0]),
                       flow.CompileOptions(strategy="dp",
                                           params=CostParams(batch=2),
                                           fidelity="simulate"))
    cm = art.ensure_model()
    for chip, pl in zip(chips, payloads):
        rep = Simulator(chip, cm.isa, engine="jax").run_model(cm)
        assert pl["cycles"] == rep.cycles
        assert pl["energy"] == dict(rep.energy())
        # and the scalar interpreter agrees on the same pinned program
        scal = Simulator(chip, cm.isa, engine="scalar").run_model(cm)
        assert_reports_identical(scal, rep)


def test_fleet_stage_decoder_matches_per_machine():
    """FleetStageDecoder batches N machines through one vmapped call;
    per-machine outputs must equal independent single-machine decodes."""
    chips = _timing_chips(3)
    machines = [machine_for(c) for c in chips]
    programs = {c: prog(c, *(_send(c, (c + 1) % 3, 16, 10 + c)
                             + _recv(c, (c - 1) % 3, 16,
                                     10 + (c - 1) % 3)
                             + [I("V_SETVL", len=40),
                                I("V_ADD", dst=1, a=2, b=3),
                                I("HALT",)]))
                for c in range(3)}
    sp = StageProgram(stage=None, schedules=[], programs=programs)
    dec = jaxsim.FleetStageDecoder(ISA, machines)
    outs = dec.decode_stage(sp.programs)
    for chip, m, ds in zip(chips, machines, outs):
        sim = Simulator(chip, ISA, engine="jax")
        out_f = vectorsim.replay_stage(sim, sp, ds)
        out_1 = jaxsim.run_stage(sim, sp)
        assert_identical(out_1, out_f)
        out_s = Simulator(chip, ISA, engine="scalar")._run_stage(sp,
                                                                 None)
        assert_identical(out_s, out_f)


# ---------------------------------------------------------------------------
# ExplorationEngine(engine="jax")
# ---------------------------------------------------------------------------


def test_explore_engine_jax_fleet(tmp_path):
    sp = timing_space(scalar_alu=(1,), vector_alu=(1, 3), wl_rate=(1, 4),
                      router=(2,))
    pts = list(sp.points())
    assert len(pts) == 4
    eng = ExplorationEngine("tiny_cnn", params=CostParams(batch=2),
                            cache=str(tmp_path / "jx"), engine="jax")
    recs = eng.evaluate(pts, fidelity="simulate")
    assert all(r.ok for r in recs)
    assert all(r.engine == "jax" for r in recs)
    # the all-defaults point shares its compile with per-point paths:
    # it must match the scalar engine bit-exactly
    default_pt = next(p for p in pts
                      if (p.scalar_alu_latency, p.vector_alu_latency,
                          p.weight_load_rows_per_cycle,
                          p.router_latency) == (1, 1, 1, 2))
    sc = ExplorationEngine("tiny_cnn", params=CostParams(batch=2),
                           cache=str(tmp_path / "sc"), engine="scalar")
    srec = sc.evaluate([default_pt], fidelity="simulate")[0]
    jrec = next(r for r in recs if r.point == default_pt)
    assert jrec.cycles == srec.cycles
    assert jrec.energy == srec.energy
    # second sweep: pure cache hits, identical payloads
    recs2 = eng.evaluate(pts, fidelity="simulate")
    assert all(r.cache_hit for r in recs2)
    assert [r.cycles for r in recs2] == [r.cycles for r in recs]
    # records round-trip the engine field
    rt = EvalRecord.from_dict(recs[0].to_dict())
    assert rt.engine == "jax"
    assert rt.row()["engine"] == "jax"


def test_jax_cache_key_is_marked(tmp_path):
    """Pinned-program (fleet) simulate results must never share cache
    entries with per-point-compiled results; cheap fidelities (no
    simulator run) keep one shared key."""
    pt = next(iter(timing_space(scalar_alu=(2,), vector_alu=(1,),
                                wl_rate=(1,), router=(2,)).points()))
    jx = ExplorationEngine("tiny_cnn", engine="jax")
    sc = ExplorationEngine("tiny_cnn", engine="scalar")
    au = ExplorationEngine("tiny_cnn")
    assert jx._key(pt, "simulate") != sc._key(pt, "simulate")
    assert sc._key(pt, "simulate") == au._key(pt, "simulate")
    assert jx._key(pt, "analytic") == au._key(pt, "analytic")


def test_timing_point_chip_roundtrip():
    """Timing-only DesignPoint fields land on the chip; the all-default
    point builds the identical historical chip object (cache keys on
    chip().to_dict() stay stable)."""
    pts = list(timing_space(scalar_alu=(1, 2), vector_alu=(1,),
                            wl_rate=(4,), router=(1,)).points())
    for p in pts:
        c = p.chip()
        assert c.core.scalar.alu_latency == p.scalar_alu_latency
        assert c.core.cim.weight_load_rows_per_cycle == 4
        assert c.noc.router_latency == 1
    from repro.explore.space import DesignPoint
    a = DesignPoint().chip().to_dict()
    b = default_chip().to_dict()
    a.pop("name"), b.pop("name")         # labels are cosmetic
    assert a == b


# ---------------------------------------------------------------------------
# func:pallas oracle backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model,kw", [
    ("tiny_cnn", {"res": 8}),
    ("transformer", dict(n_layers=1, d_model=128, n_heads=4, seq=16,
                         vocab=64)),
])
def test_func_pallas_bit_exact(model, kw):
    """The Pallas bit-serial oracle must agree bit-exactly with the
    numpy oracle (check=True raises on any group mismatch)."""
    art = flow.compile(model, CHIP, flow.CompileOptions(
        strategy="dp", batch=2, workload_kw=kw, fidelity="analytic"))
    rep = art.evaluate("func:pallas")
    assert rep.backend == "func:pallas"
    assert rep.outputs and all(a.dtype == np.int8
                               for a in rep.outputs.values())
    assert rep.cycles == 0.0             # no timing claim


def test_func_pallas_rejects_partial_tensors():
    art = flow.compile("tiny_cnn", CHIP, flow.CompileOptions(
        strategy="dp", batch=1, workload_kw={"res": 8},
        fidelity="analytic"))
    with pytest.raises(TypeError):
        art.evaluate("func:pallas", inputs=np.zeros((1, 8, 8, 3),
                                                    dtype=np.int8))


def test_auto_interpret_memoized_and_env_override(monkeypatch):
    from repro.kernels import ops
    ops._auto_interpret.cache_clear()
    monkeypatch.setenv(ops._INTERPRET_ENV, "1")
    assert ops._auto_interpret() is True
    # memoized: a changed env is not re-read until the cache clears
    monkeypatch.setenv(ops._INTERPRET_ENV, "0")
    assert ops._auto_interpret() is True
    ops._auto_interpret.cache_clear()
    assert ops._auto_interpret() is False
    ops._auto_interpret.cache_clear()
    monkeypatch.delenv(ops._INTERPRET_ENV)
    import jax
    assert ops._auto_interpret() is (jax.default_backend() != "tpu")
    ops._auto_interpret.cache_clear()


# ---------------------------------------------------------------------------
# hypothesis: randomized decodable programs, jax == scalar
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    _N_CORES = 3

    @st.composite
    def stage_programs(draw):
        """Random multi-core stage in the decodable subset (the
        construction from test_vectorsim: sends before recvs within a
        phase, unique streams, all-core SYNC between phases)."""
        rng_local = st.sampled_from([
            lambda d: [I("NOP",)],
            lambda d: [I("S_ADDI", dst=d.draw(st.integers(1, 5)), a=0,
                         imm=d.draw(st.integers(-100, 100)))],
            lambda d: [I("S_LUI", dst=d.draw(st.integers(1, 5)),
                         imm=d.draw(st.integers(0, 50)))],
            lambda d: [I("S_LD", dst=6, base=1, off=0)],
            lambda d: [I("V_SETVL", len=d.draw(st.integers(1, 200)))],
            lambda d: [I("CIM_CFG", sreg=SREG["V_REP"],
                         imm=d.draw(st.integers(0, 4)))],
            lambda d: [I("V_ADD", dst=1, a=2, b=3)],
            lambda d: [I("V_QUANT", dst=1, a=2, b=0,
                         flags=d.draw(st.sampled_from([0, 4])))],
            lambda d: [I("V_EXP", dst=1, a=2, b=0)],
            lambda d: [I("CIM_CFG", sreg=SREG["MG_NLEN"],
                         imm=d.draw(st.integers(1, 64)))],
            lambda d: [I("CIM_LOAD", mg=d.draw(st.integers(0, 3)),
                         src=1, rows=d.draw(st.integers(1, 128)))],
            lambda d: [I("CIM_CFG", sreg=SREG["MG_MASK_LO"],
                         imm=d.draw(st.integers(0, 15)))],
            lambda d: [I("CIM_MVM", dst=1, src=2,
                         rep=d.draw(st.integers(1, 8)),
                         acc=d.draw(st.sampled_from([0, 1])))],
            lambda d: [I("S_ADDI", dst=7, a=0,
                         imm=d.draw(st.integers(1, 300))),
                       I("GLD", dst=1, gaddr=2, size=7)],
            lambda d: [I("S_ADDI", dst=8, a=0,
                         imm=d.draw(st.integers(1, 64))),
                       I("BCAST", src=1, size=8)],
        ])

        class _D:
            draw = staticmethod(draw)

        n_phases = draw(st.integers(1, 2))
        chunks = {c: [] for c in range(_N_CORES)}
        stream = 0
        for phase in range(n_phases):
            sends = {c: [] for c in chunks}
            recvs = {c: [] for c in chunks}
            for _ in range(draw(st.integers(0, 3))):
                src = draw(st.integers(0, _N_CORES - 1))
                dst = draw(st.integers(0, _N_CORES - 1))
                if src == dst:
                    continue
                size = draw(st.integers(1, 96))
                sends[src].extend(_send(src, dst, size, stream))
                recvs[dst].extend(_recv(dst, src, size, stream))
                stream += 1
            for c in chunks:
                ops = []
                for _ in range(draw(st.integers(0, 6))):
                    ops.extend(draw(rng_local)(_D))
                chunks[c].extend(sends[c] + ops + recvs[c])
                chunks[c].append(I("SYNC", barrier=phase))
        programs = {}
        for c, body in chunks.items():
            if draw(st.booleans()):
                body.append(I("HALT",))
            programs[c] = prog(c, *body)
        return programs

    @settings(max_examples=25, deadline=None)
    @given(stage_programs())
    def test_random_programs_identical(programs):
        assert_identical(*run_stage_both(programs))

    @settings(max_examples=10, deadline=None)
    @given(stage_programs())
    def test_random_programs_fleet_identical(programs):
        """vmapped fleet decode == per-machine scalar interpreter on
        randomized programs across timing-diverse machines."""
        chips = _timing_chips(3)
        sp = StageProgram(stage=None, schedules=[], programs=programs)
        dec = jaxsim.FleetStageDecoder(
            ISA, [machine_for(c) for c in chips])
        outs = dec.decode_stage(sp.programs)
        for chip, ds in zip(chips, outs):
            out_f = vectorsim.replay_stage(
                Simulator(chip, ISA, engine="jax"), sp, ds)
            out_s = Simulator(chip, ISA,
                              engine="scalar")._run_stage(sp, None)
            assert_identical(out_s, out_f)

"""Engine-equivalence suite: the array-batched replay engine vs the
reference event engine, and the vectorized trace generators vs their
scalar reference loops.

The contract under test is *byte identity*: for every supported
configuration, ``metrics_json`` from the array engine equals the event
engine's output modulo the self-describing ``engine`` key — including
the PR-9 degradation paths (shedding, retries, deadlines, goodput) and
KV-pressure schedules where admission blocks mid-trace.  Trace
generators must reproduce the committed traces bit-for-bit
(regenerating ``benchmarks/serving_trace.json`` must be a no-op diff).

Synthetic ``StepCostTable.from_costs`` tables keep the suite fast; the
CI serving gate (``benchmarks/bench_serve.py --smoke``) additionally
runs the equivalence check against the compiled trace-fidelity table.
"""

import os
import random
import warnings

import numpy as np
import pytest

from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                         StreamingPercentiles, VecMT, load_trace,
                         make_policy, metrics_json, percentile,
                         poisson_trace, poisson_trace_arrays,
                         summarize, summarize_soa)
from repro.serve.metrics import RequestRecord
from repro.serve.trace_replay import (_bursty_trace_scalar,
                                      _poisson_trace_scalar,
                                      bursty_trace)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TRACE_PATH = os.path.join(_ROOT, "benchmarks", "serving_trace.json")

# mirrors benchmarks/bench_faults.py serving_overload
FAULT_KW = dict(deadline_s=0.002, max_queue=4, max_retries=2,
                retry_backoff_s=0.0005)


def _table(max_new=64, decode_base=30e-6, decode_per=2e-6):
    cfg = ServeModelCfg(max_prompt=64, max_new=max_new)
    pb = [1, 2, 4, 8, 16, 32, 64]
    db, b = [], 1
    while b < cfg.max_seq:
        db.append(b)
        b *= 2
    db.append(cfg.max_seq)
    return StepCostTable.from_costs(
        cfg,
        prefill_s={b: 2e-6 * b for b in pb},
        decode_base_s={b: decode_base + 0.01e-6 * b for b in db},
        decode_per_seq_s={b: decode_per + 0.002e-6 * b for b in db},
        prefill_base_s={b: 1.5e-6 * b for b in pb},
        prefill_per_seq_s={b: 0.5e-6 * b for b in pb},
    )


def _run(table, trace, policy="continuous", max_batch=8,
         max_sim_s=None, **kw):
    sim = ServeSim(table, make_policy(policy, max_batch), **kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return sim.run(trace, max_sim_s=max_sim_s)


def _assert_equiv(table, trace, policy="continuous", **kw):
    out = {}
    for eng in ("event", "array"):
        m = dict(_run(table, trace, policy, engine=eng, **kw))
        assert m.pop("engine") == eng
        out[eng] = metrics_json(m)
    assert out["event"] == out["array"]


# --------------------------------------------------------------------
# engine equivalence
# --------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["static", "continuous"])
def test_committed_trace_byte_identical(policy):
    _assert_equiv(_table(), load_trace(TRACE_PATH), policy)


def test_degradation_config_byte_identical():
    # the BENCH_faults serving_overload shape: shedding + retries +
    # deadlines all active, metrics carry the goodput keys
    hot = poisson_trace(300000.0, 200, seed=1)
    _assert_equiv(_table(), hot, "continuous", **FAULT_KW)
    m = _run(_table(), hot, engine="array", **FAULT_KW)
    assert m["shed_requests"] > 0 and m["timeout_requests"] > 0


@pytest.mark.parametrize("seed", [0, 5])
@pytest.mark.parametrize("policy", ["static", "continuous"])
def test_kv_pressure_byte_identical(policy, seed):
    # long generations + a KV budget of ~4 concurrent max-length
    # requests: admission blocks mid-trace, exercising the horizon
    # rollback and arrival-cut paths
    table = _table(max_new=1024)
    cap = table.cfg.kv_bytes(table.cfg.max_seq) * 4
    tr = poisson_trace(3000.0, 400, seed=seed, min_prompt=4,
                       max_prompt=64, min_new=16, max_new=1024)
    _assert_equiv(table, tr, policy, kv_capacity_bytes=cap)


@pytest.mark.parametrize("max_batch", [1, 2, 32])
def test_batch_width_byte_identical(max_batch):
    tr = poisson_trace(50000.0, 300, seed=7)
    _assert_equiv(_table(), tr, "continuous", max_batch=max_batch)


def test_bursty_trace_byte_identical():
    tr = bursty_trace(20000.0, 300, seed=3)
    _assert_equiv(_table(), tr, "continuous")
    _assert_equiv(_table(), tr, "static")


def test_tiny_traces_byte_identical():
    _assert_equiv(_table(), poisson_trace(1000.0, 1, seed=0))
    # all-single-token generations never reach the decode engine
    tr = poisson_trace(1000.0, 20, seed=2, min_new=1, max_new=1)
    _assert_equiv(_table(), tr)


def test_overload_diagnostic_parity():
    table = _table(max_new=1024)
    tr = poisson_trace(1e6, 2000, seed=3, min_new=16, max_new=1024)
    msgs = {}
    for eng in ("event", "array"):
        with pytest.raises(RuntimeError) as ei:
            _run(table, tr, engine=eng, max_sim_s=0.5)
        msgs[eng] = str(ei.value)
    assert msgs["event"] == msgs["array"]


def test_metrics_header_roundtrip():
    tr = poisson_trace(5000.0, 50, seed=0)
    for eng in ("event", "array"):
        m = _run(_table(), tr, engine=eng)
        assert m["engine"] == eng
        assert m["prefill_policy"] == "fifo"
    m = _run(_table(), tr, engine="array", prefill_policy="batched")
    assert m["prefill_policy"] == "batched"


# --------------------------------------------------------------------
# prefill policies
# --------------------------------------------------------------------

def _prefill_setup():
    # prompt-heavy over-capacity regime (see bench_serve): prompts all
    # land in the 64 bucket but average ~48 actual tokens, decode light
    table = _table(max_new=8, decode_base=10e-6, decode_per=1e-6)
    tr = poisson_trace(9000.0, 2000, seed=11, min_prompt=33,
                       max_prompt=64, min_new=2, max_new=8)
    return table, tr


def test_chunked_beats_fifo_p99_ttft_over_capacity():
    table, tr = _prefill_setup()
    fifo = _run(table, tr, max_batch=16, prefill_policy="fifo")
    chunked = _run(table, tr, max_batch=16, prefill_policy="chunked",
                   chunk_tokens=64)
    assert chunked["ttft_s"]["p99"] < fifo["ttft_s"]["p99"]
    assert chunked["ttft_s"]["p50"] < fifo["ttft_s"]["p50"]
    # same tokens delivered — chunking reshapes latency, not work
    assert chunked["tokens"] == fifo["tokens"]


def test_batched_prefill_beats_fifo_ttft():
    table, tr = _prefill_setup()
    fifo = _run(table, tr, max_batch=16, prefill_policy="fifo")
    batched = _run(table, tr, max_batch=16, prefill_policy="batched",
                   prefill_max_batch=8)
    assert batched["ttft_s"]["p99"] < fifo["ttft_s"]["p99"]
    assert batched["tokens"] == fifo["tokens"]


def test_batched_prefill_work_conserving():
    # at a trickle rate every batch has one member, priced base+per —
    # the affine fit at batch 1, not the batch-1 verbatim cost
    table = _table()
    tr = poisson_trace(1.0, 10, seed=0)
    m = _run(table, tr, prefill_policy="batched")
    assert m["requests"] == 10


def test_prefill_policy_validation():
    table = _table()
    pol = make_policy("continuous", 8)
    with pytest.raises(ValueError, match="event engine"):
        ServeSim(table, pol, engine="event", prefill_policy="batched")
    with pytest.raises(ValueError, match="max_queue"):
        ServeSim(table, pol, max_queue=4, prefill_policy="chunked")
    with pytest.raises(ValueError, match="engine"):
        ServeSim(table, pol, engine="heapq")
    with pytest.raises(ValueError, match="prefill_policy"):
        ServeSim(table, pol, prefill_policy="sarathi")


# --------------------------------------------------------------------
# vectorized trace generators vs scalar reference
# --------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 12345])
def test_poisson_trace_matches_scalar(seed):
    assert poisson_trace(5000.0, 200, seed=seed) == \
        _poisson_trace_scalar(5000.0, 200, seed=seed)


def test_poisson_trace_committed_params_bitexact():
    # exactly the committed benchmarks/serving_trace.json parameters:
    # regenerating the trace must be a no-op diff
    vec = poisson_trace(5000.0, 200, seed=0, max_prompt=64, max_new=64)
    ref = _poisson_trace_scalar(5000.0, 200, seed=0, max_prompt=64,
                                max_new=64)
    assert vec == ref
    on_disk = load_trace(TRACE_PATH)
    assert vec == on_disk


def test_poisson_trace_arrays_match_requests():
    t, p, g = poisson_trace_arrays(7000.0, 500, seed=4)
    reqs = poisson_trace(7000.0, 500, seed=4)
    assert t.tolist() == [r.t_arrive for r in reqs]
    assert p.tolist() == [r.prompt_len for r in reqs]
    assert g.tolist() == [r.gen_len for r in reqs]


@pytest.mark.parametrize("seed", [0, 3, 99])
def test_bursty_trace_matches_scalar(seed):
    assert bursty_trace(4000.0, 150, seed=seed) == \
        _bursty_trace_scalar(4000.0, 150, seed=seed)


def test_bursty_trace_ulp_edge_terminates():
    # rate 8.0 / burst 3.0 / seed 0 lands t exactly on a phase edge at
    # arrival 36 (t=4.6): edge becomes +3.3e-16 with t + edge == t, so
    # the pre-fix phase walk could not advance the clock and spun
    # forever.  Pin that the walk terminates and both generators agree.
    scalar = _bursty_trace_scalar(8.0, 100, seed=0, burst=3.0)
    vec = bursty_trace(8.0, 100, seed=0, burst=3.0)
    assert len(scalar) == 100
    assert scalar == vec


@pytest.mark.parametrize("seed", [0, 1, 2 ** 33 + 7])
def test_vecmt_bit_identical_to_cpython(seed):
    mt = VecMT(seed)
    ref = random.Random(seed)
    words = mt.peek(2000)
    assert words.tolist() == [ref.getrandbits(32) for _ in range(2000)]


def test_vecmt_consume_splices_with_cpython_stream():
    # after a batched draw, a fresh CPython Random fast-forwarded by
    # the same word count continues the identical stream
    mt = VecMT(42)
    n = 137
    from repro.serve.rng import uniform_randbelow_batch
    u, (a, b) = uniform_randbelow_batch(mt, n, (61, 61))
    ref = random.Random(42)
    for _ in range(n):
        ref.random()
        ref.randint(0, 60)
        ref.randint(0, 60)
    assert u[0] != u[-1]
    assert mt.consumed > 0
    assert mt.peek(2)[0] == ref.getrandbits(32)


# --------------------------------------------------------------------
# metrics: SoA summarizer and streaming percentiles
# --------------------------------------------------------------------

def test_summarize_soa_matches_records():
    rng = random.Random(5)
    recs = []
    for i in range(200):
        ta = rng.random()
        pre = ta + rng.random() * 0.01
        first = pre + rng.random() * 0.01
        gen = rng.randint(1, 64)
        recs.append(RequestRecord(
            rid=i, t_arrive=ta, prompt_len=rng.randint(4, 64),
            gen_len=gen, t_prefill_start=pre, t_first_token=first,
            t_complete=first + (gen - 1) * 2e-5))
    a = summarize(recs, extra={"k": 1})
    b = summarize_soa(
        np.array([r.t_arrive for r in recs]),
        np.array([r.gen_len for r in recs]),
        np.array([r.t_first_token for r in recs]),
        np.array([r.t_complete for r in recs]),
        extra={"k": 1})
    assert metrics_json(a) == metrics_json(b)


def test_streaming_percentiles_converge():
    rng = random.Random(0)
    xs = [rng.gauss(10.0, 2.0) for _ in range(50_000)]
    sp = StreamingPercentiles()
    sp.extend(xs)
    assert sp.count == len(xs)
    for q in (50, 95, 99):
        exact = percentile(xs, q)
        assert sp.get(q) == pytest.approx(exact, rel=0.02)


def test_streaming_percentiles_tiny_sample_exact():
    sp = StreamingPercentiles()
    sp.extend([3.0, 1.0, 2.0])
    assert sp.get(50) == 2.0


def test_streaming_mode_in_simulator():
    tr = poisson_trace(5000.0, 300, seed=6)
    exact = _run(_table(), tr)
    stream = _run(_table(), tr, percentile_mode="streaming")
    # same folds for counts/means, approximate percentiles
    assert stream["tokens"] == exact["tokens"]
    assert stream["ttft_s"]["mean"] == exact["ttft_s"]["mean"]
    assert stream["ttft_s"]["p99"] == pytest.approx(
        exact["ttft_s"]["p99"], rel=0.25)

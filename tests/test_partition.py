"""Alg. 1 DP partitioning: closure enumeration, DP optimality (vs an
independent brute force), strategy dominance, capacity handling."""

import math

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional "
                           "hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core import workloads
from repro.core.arch import default_chip
from repro.core.graph import CondensedGraph, Group
from repro.core.mapping import CostParams, mg_tiles, optimal_mapping
from repro.core.partition import (dependency_closures, dp_partition,
                                  greedy_partition, partition, prefix_closures)

CHIP = default_chip()
SMALL_CHIP = default_chip(n_cores=4, mesh_cols=2, n_macro_groups=2,
                          macros_per_group=2)


# ---------------------------------------------------------------------------
# Closure enumeration
# ---------------------------------------------------------------------------


def _chain(n: int) -> CondensedGraph:
    groups = [Group(idx=i, name=f"g{i}", op_ids=(i,), anchor=i,
                    preds=(i - 1,) if i else (), gemm_m=4, gemm_k=64,
                    gemm_n=64, weight_bytes=64 * 64, macs=4 * 64 * 64,
                    in_bytes=256, out_bytes=256)
              for i in range(n)]
    return CondensedGraph("chain", groups)


def test_chain_closures_are_prefixes():
    cg = _chain(6)
    assert dependency_closures(cg) == prefix_closures(cg)


def test_antichain_closures_are_all_subsets():
    groups = [Group(idx=i, name=f"g{i}", op_ids=(i,), anchor=i, preds=(),
                    gemm_m=1, gemm_k=8, gemm_n=8, weight_bytes=64, macs=64,
                    in_bytes=8, out_bytes=8) for i in range(4)]
    cg = CondensedGraph("anti", groups)
    assert sorted(dependency_closures(cg)) == sorted(range(16))


def _random_cg(draw) -> CondensedGraph:
    n = draw(st.integers(1, 6))
    groups = []
    for i in range(n):
        preds = tuple(sorted(draw(st.sets(st.integers(0, i - 1), max_size=2))
                             )) if i else ()
        k = draw(st.sampled_from([64, 256, 512, 2048]))
        cout = draw(st.sampled_from([8, 64, 256]))
        m = draw(st.sampled_from([1, 16, 196]))
        groups.append(Group(
            idx=i, name=f"g{i}", op_ids=(i,), anchor=i, preds=preds,
            gemm_m=m, gemm_k=k, gemm_n=cout, weight_bytes=k * cout,
            macs=m * k * cout, vector_work={"alu": m * cout},
            in_bytes=m * k, out_bytes=m * cout))
    return CondensedGraph("rand", groups)


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_closures_are_downward_closed(data):
    cg = _random_cg(data.draw)
    masks = dependency_closures(cg)
    assert 0 in masks and (1 << len(cg)) - 1 in masks
    assert len(set(masks)) == len(masks)
    for m in masks:
        for g in cg:
            if m & (1 << g.idx):
                for p in g.preds:
                    assert m & (1 << p), "closure not predecessor-closed"


# ---------------------------------------------------------------------------
# DP optimality vs independent brute force
# ---------------------------------------------------------------------------


def _brute_force_cost(cg: CondensedGraph, chip, params) -> float:
    """Enumerate ALL valid stage sequences directly (no closure lattice)."""
    n = len(cg)
    full = (1 << n) - 1
    pred_mask = [0] * n
    for g in cg:
        for p in g.preds:
            pred_mask[g.idx] |= 1 << p

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def best(done: int) -> float:
        if done == full:
            return 0.0
        avail = [v for v in range(n) if not done & (1 << v)]
        best_c = math.inf
        # all non-empty subsets of remaining nodes
        m = len(avail)
        for pick in range(1, 1 << m):
            stage = sum(1 << avail[b] for b in range(m) if pick & (1 << b))
            # executable: every member's preds inside done|stage
            ok = all((pred_mask[v] & ~(done | stage)) == 0
                     for v in range(n) if stage & (1 << v))
            if not ok:
                continue
            gids = [v for v in range(n) if stage & (1 << v)]
            plan = optimal_mapping(cg, gids, chip, params)
            if plan is None:
                continue
            c = plan.latency_cycles() + best(done | stage)
            best_c = min(best_c, c)
        return best_c

    return best(0)


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_dp_matches_brute_force(data):
    cg = _random_cg(data.draw)
    params = CostParams(batch=4)
    res = dp_partition(cg, SMALL_CHIP, params)
    brute = _brute_force_cost(cg, SMALL_CHIP, params)
    assert res.latency_cycles() == pytest.approx(brute, rel=1e-9)


# ---------------------------------------------------------------------------
# Strategy behaviour on the paper's workloads (small resolution for speed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["resnet18", "mobilenetv2"])
def test_dp_dominates_baselines(name):
    cg = workloads.build(name, res=64).condense()
    params = CostParams(batch=16)
    lat = {s: partition(cg, CHIP, s, params).latency_cycles()
           for s in ("generic", "cim-mlc", "dp")}
    assert lat["dp"] <= lat["cim-mlc"] * (1 + 1e-9)
    assert lat["dp"] <= lat["generic"] * (1 + 1e-9)


def test_oversized_group_streams_in_rounds():
    """VGG19 fc1 (~103 MB) exceeds chip capacity -> rounds > 1, own stage."""
    cg = workloads.build("vgg19").condense()
    fc1 = next(g for g in cg if "fc1" in g.name)
    assert mg_tiles(fc1, CHIP) > CHIP.n_cores * CHIP.core.cim.n_macro_groups
    res = partition(cg, CHIP, "dp")
    stage = next(s for s in res.stages if fc1.idx in s.gids)
    assert stage.gids == (fc1.idx,)
    alloc = stage.allocs[0]
    assert alloc.rounds > 1


def test_streaming_rounds_cycle_above_coresidents():
    """Weight streaming on a time-shared core (the deleted OpLevelError):
    a streaming group placed with an additive co-resident cycles its
    rounds through its OWN slot range, regardless of gid order — the
    op-level planner lays additive groups down first."""
    from repro.core.graph import Graph
    from repro.core.mapping import StagePlan, _alloc_group
    from repro.core.oplevel import plan_stage

    chip = default_chip(n_cores=1, mesh_cols=1, n_macro_groups=4,
                        macros_per_group=1)
    g = Graph("shared")
    x = g.input("x", (4096,))
    a = g.linear("big", x, cout=8, bias=False)   # col spans 8 > 4 slots
    g.linear("small", a, cout=8, bias=False)     # 1 additive tile
    cg = g.condense()
    params = CostParams(batch=1)
    allocs = [_alloc_group(cg[0], chip, params, 1, True),
              _alloc_group(cg[1], chip, params, 1, False)]
    sp = StagePlan((0, 1), allocs, chip, params, shared_cores=True,
                   bases=[0, 0]).bind(cg)
    big, small = plan_stage(cg, sp, chip)
    assert small.weight_source == "static"
    assert {asg.slot for asg in small.replicas[0].assigns} == {0}
    assert big.weight_source == "streamed" and big.n_rounds > 1
    slots = {asg.slot for asg in big.replicas[0].assigns}
    assert 0 not in slots and slots <= {1, 2, 3}


def test_partition_covers_all_groups_once():
    cg = workloads.build("efficientnetb0", res=64).condense()
    for strat in ("generic", "cim-mlc", "dp"):
        res = partition(cg, CHIP, strat)
        covered = sorted(i for s in res.stages for i in s.gids)
        assert covered == list(range(len(cg)))


def test_stage_dependencies_respected():
    cg = workloads.build("resnet18", res=64).condense()
    res = partition(cg, CHIP, "dp")
    done = set()
    for s in res.stages:
        for gid in s.gids:
            assert all(p in done or p in s.gids for p in cg[gid].preds)
        done |= set(s.gids)


def test_energy_events_positive():
    cg = workloads.build("mobilenetv2", res=64).condense()
    res = partition(cg, CHIP, "dp")
    ev = res.energy_events()
    assert ev["cim_macro_passes"] > 0
    assert ev["static_core_cycles"] > 0
    from repro.core.energy import energy_breakdown
    bd = energy_breakdown(ev)
    assert bd["total"] > 0

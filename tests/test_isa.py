"""ISA conformance: encode/decode round-trip, extensibility, error checks."""

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the optional "
                           "hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core.isa import (FORMATS, Instr, InstrDescriptor, Isa, IsaError,
                            Program, default_isa)

ISA = default_isa()


def _operand_bounds(desc):
    """(semantic name -> (lo, hi)) for each operand of a descriptor."""
    widths = dict(FORMATS[desc.fmt])
    out = {}
    for sem, enc in desc.operands.items():
        w = widths[enc]
        if enc.startswith("imm"):
            out[sem] = (-(1 << (w - 1)), (1 << (w - 1)) - 1)
        else:
            out[sem] = (0, (1 << w) - 1)
    return out


@given(st.data())
@settings(max_examples=200, deadline=None)
def test_encode_decode_roundtrip(data):
    desc = data.draw(st.sampled_from(ISA.descriptors))
    args = {}
    for sem, (lo, hi) in _operand_bounds(desc).items():
        args[sem] = data.draw(st.integers(lo, hi))
    ins = ISA.instr(desc.name, **args)
    word = ISA.encode(ins)
    assert 0 <= word < (1 << 32)
    back = ISA.decode(word)
    assert back.op == desc.name
    assert back.args == args


def test_all_descriptors_unique_and_valid():
    names = [d.name for d in ISA.descriptors]
    assert len(names) == len(set(names))
    # at least the paper's three instruction categories are populated
    units = {d.unit for d in ISA.descriptors}
    assert {"cim", "vector", "scalar", "noc", "control"} <= units


def test_field_overflow_rejected():
    with pytest.raises(IsaError):
        ISA.encode(ISA.instr("S_ADDI", dst=1, a=2, imm=1 << 20))
    with pytest.raises(IsaError):
        ISA.encode(ISA.instr("CIM_MVM", dst=40, src=0, rep=0))


def test_unknown_operand_rejected():
    with pytest.raises(IsaError):
        ISA.instr("NOP", bogus=1)


def test_extensibility_template():
    """New op integrates via a descriptor alone (paper §III-B)."""
    isa = default_isa()
    d = InstrDescriptor(name="V_SORT", opcode=63, fmt="R", unit="vector",
                        operands={"dst": "rd", "a": "rs1"},
                        latency_class="vec_special",
                        energy_class="vector_alu")
    isa.register(d)
    ins = isa.instr("V_SORT", dst=3, a=4)
    assert isa.decode(isa.encode(ins)).args == {"dst": 3, "a": 4}
    # duplicate name / opcode+funct collision rejected
    with pytest.raises(IsaError):
        isa.register(d)


def test_opcode_format_collision_rejected():
    isa = default_isa()
    with pytest.raises(IsaError):
        # opcode 0 is CIM_MVM with fmt C; can't rebind to fmt R
        isa.register(InstrDescriptor(name="X", opcode=0, fmt="R",
                                     unit="cim"))


def test_program_encode_and_disassemble():
    p = Program()
    p.append(ISA.instr("CIM_CFG", sreg=3, imm=8))
    p.append(ISA.instr("CIM_MVM", dst=1, src=2, rep=4))
    p.append(ISA.instr("HALT"))
    words = p.encode(ISA)
    assert words.dtype.name == "uint32" and len(words) == 3
    text = p.disassemble(ISA)
    assert "CIM_MVM" in text and "HALT" in text


def test_signed_immediates_roundtrip():
    ins = ISA.instr("S_ADDI", dst=1, a=0, imm=-42)
    assert ISA.decode(ISA.encode(ins)).args["imm"] == -42

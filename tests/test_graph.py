"""Graph IR + workload builders: geometry, condensation invariants."""

import math

import pytest

from repro.core import workloads
from repro.core.graph import CondensedGraph, Graph, GraphError, Op

# Published parameter / MAC counts (224x224, 1000 classes).
KNOWN = {
    # name: (params M, MACs G) with tolerance
    "resnet18": (11.69, 1.82),
    "vgg19": (143.7, 19.6),
    "mobilenetv2": (3.5, 0.30),
    "efficientnetb0": (5.3, 0.39),
}


@pytest.mark.parametrize("name", sorted(KNOWN))
def test_workload_matches_published_stats(name):
    g = workloads.build(name)
    params_m, macs_g = KNOWN[name]
    # weights are INT8 -> bytes == param count
    assert g.total_weight_bytes / 1e6 == pytest.approx(params_m, rel=0.03)
    assert g.total_macs / 1e9 == pytest.approx(macs_g, rel=0.05)


@pytest.mark.parametrize("name", sorted(KNOWN) + ["transformer", "tiny_cnn"])
def test_condensation_preserves_totals(name):
    g = workloads.build(name)
    cg = g.condense()
    assert cg.total_weight_bytes == g.total_weight_bytes
    assert cg.total_macs == g.total_macs
    # every MVM op anchors exactly one group
    n_mvm = sum(1 for o in g.ops if o.is_mvm)
    n_anchored = sum(1 for grp in cg if grp.is_mvm)
    assert n_anchored == n_mvm
    # groups partition all non-input ops
    covered = sorted(i for grp in cg for i in grp.op_ids)
    non_input = sorted(o.idx for o in g.ops if o.kind != "input")
    assert covered == non_input


@pytest.mark.parametrize("name", sorted(KNOWN))
def test_condensed_graph_topological(name):
    cg = workloads.build(name).condense()
    for grp in cg:
        assert all(p < grp.idx for p in grp.preds)
    masks = cg.ancestor_masks()
    # ancestors are transitively closed
    for grp in cg:
        for p in grp.preds:
            assert masks[grp.idx] & masks[p] == masks[p]


def test_conv_geometry():
    g = Graph("t")
    x = g.input("x", (8, 8, 3))
    y = g.conv("c", x, cout=16, k=3, stride=2, use_bn=False)
    op = g.ops[y]
    assert op.out_shape == (4, 4, 16)
    assert (op.gemm_m, op.gemm_k, op.gemm_n) == (16, 27, 16)
    assert op.weight_bytes == 27 * 16
    assert op.macs == 16 * 27 * 16


def test_depthwise_geometry():
    g = Graph("t")
    x = g.input("x", (8, 8, 32))
    y = g.conv("dw", x, cout=32, k=3, groups=32, use_bn=False)
    op = g.ops[y]
    assert op.kind == "dwconv"
    assert (op.gemm_k, op.gemm_n, op.groups) == (9, 1, 32)
    assert op.weight_bytes == 9 * 32
    assert op.macs == 64 * 9 * 32


def test_dangling_input_rejected():
    g = Graph("t")
    with pytest.raises(GraphError):
        g.add(Op(name="bad", kind="relu", inputs=(5,), out_shape=(1,)))


def test_se_block_fuses_into_groups():
    """EfficientNet SE: pool->fc->fc->scale must condense without creating
    anchor-less groups, and the condensed graph stays near-linear."""
    cg = workloads.build("efficientnetb0").condense()
    anchorless = [grp for grp in cg if not grp.is_mvm]
    assert len(anchorless) == 0
    # skip connections keep preds <= 2
    assert max(len(grp.preds) for grp in cg) <= 2

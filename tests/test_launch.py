"""Launch layer: sharding spec trees, HLO analysis, planner."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, STANDARD_SHAPES
from repro.core.planner import PodSpec, plan_parallelism
from repro.launch import analysis, sharding, steps
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def mesh():
    # logical 16x16 mesh built from 1 real device? jax.make_mesh needs
    # real devices; use a (1,1) mesh for structure tests and a fake-shape
    # helper for divisibility logic.
    return make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Just enough of a Mesh for the pure-divisibility helpers."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        out = 1
        for v in self.shape.values():
            out *= v
        return out


PROD = _FakeMesh({"data": 16, "model": 16})


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_match_param_tree(name, mesh):
    """Spec tree structure must match the parameter tree exactly, with
    every sharded dim divisible on the PRODUCTION mesh."""
    cfg = ARCHS[name]
    params = steps.abstract_params(cfg)
    specs = sharding.param_specs(cfg, PROD)
    # tree.map raises on structure mismatch
    merged = jax.tree.map(lambda s, p: (tuple(s), p.shape), specs, params,
                          is_leaf=lambda x: isinstance(x, P))
    # every sharded dim must divide the corresponding param dim on the
    # production mesh
    def check(pair):
        spec, shape = pair
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            size = PROD.shape["model"]
            assert shape[dim] % size == 0, (name, spec, shape)
    jax.tree.map(check, merged,
                 is_leaf=lambda x: isinstance(x, tuple)
                 and len(x) == 2 and isinstance(x[0], tuple))


def test_head_sharding_choices():
    hs = lambda n: sharding.head_sharding_choice(ARCHS[n], PROD)
    assert hs("phi3-medium-14b") == "head_dim"       # 40 heads, kv 10
    assert hs("deepseek-coder-33b") == "head_dim"    # 56 heads, kv 8
    assert hs("deepseek-v3-671b") == "heads"         # 128 MLA heads
    assert hs("olmoe-1b-7b") == "heads"              # 16 heads, kv 16
    assert hs("whisper-small") == "head_dim"         # 12 heads


def test_usable_data_axes_drops_for_small_batch():
    assert sharding.usable_data_axes(PROD, 256) == ("data",)
    assert sharding.usable_data_axes(PROD, 1) == ()
    three = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert sharding.usable_data_axes(three, 256) == ("pod", "data")
    assert sharding.usable_data_axes(three, 16) == ("data",)
    assert sharding.usable_data_axes(three, 1) == ()


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %all-reduce.1 = bf16[16,4096,448]{2,1,0} all-reduce(%x), replica_groups=...
  %ag = f32[1024,512]{1,0} all-gather(%y), dimensions={0}
  %rs = bf16[64,128]{1,0} reduce-scatter(%z), dimensions={0}
  %cp-start = (f32[8,8]{1,0}, f32[8,8]{1,0}) collective-permute-start(%w)
  %dot.5 = f32[128,128]{1,0} dot(%a, %b)
"""


def test_collective_bytes_parsing():
    coll = analysis.collective_bytes(HLO_SAMPLE)
    assert coll["all-reduce"] == 16 * 4096 * 448 * 2
    assert coll["all-gather"] == 1024 * 512 * 4
    assert coll["reduce-scatter"] == 64 * 128 * 2
    assert coll["collective-permute"] == 2 * 8 * 8 * 4
    assert coll["all-to-all"] == 0
    assert coll["count"] == 4


def test_roofline_terms_dominance():
    cost = {"flops": 197e12, "bytes accessed": 819e9 / 2}
    coll = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
            "all-to-all": 0, "collective-permute": 0}
    t = analysis.roofline_terms(cost, coll)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.dominant == "compute"
    t2 = analysis.roofline_terms(cost, coll, extra_link_bytes=200e9)
    assert t2.dominant == "collective"


def test_model_flops_train_vs_decode():
    cfg = ARCHS["phi3-medium-14b"]
    tr = analysis.model_flops(cfg, STANDARD_SHAPES["train_4k"], 256)
    de = analysis.model_flops(cfg, STANDARD_SHAPES["decode_32k"], 256)
    n = cfg.param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096 / 256)
    assert de == pytest.approx(2 * n * 128 / 256)


def test_moe_active_params_subtracts_inactive_experts():
    cfg = ARCHS["olmoe-1b-7b"]
    active = analysis._active_params(cfg)
    assert active < 0.35 * cfg.param_count()      # 8 of 64 experts


# ---------------------------------------------------------------------------
# Planner (Alg. 1 at pod scale)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_planner_produces_valid_plan(name):
    cfg = ARCHS[name]
    plan = plan_parallelism(cfg, STANDARD_SHAPES["train_4k"])
    assert plan.stages, name
    # stages tile the block range exactly
    covered = []
    for s in plan.stages:
        covered.extend(range(*s.blocks))
    assert covered == list(range(cfg.n_blocks))
    # each stage's replica fits the HBM budget
    pod = plan.pod
    for s in plan.stages:
        assert s.bytes_per_chip <= pod.hbm_bytes * pod.hbm_budget_frac \
            * 1.001
        assert s.chips <= pod.n_chips
    assert plan.est_step_s > 0 and plan.tokens_per_s > 0


def test_planner_big_models_need_more_stages():
    small = plan_parallelism(ARCHS["mamba2-780m"],
                             STANDARD_SHAPES["train_4k"])
    big = plan_parallelism(ARCHS["deepseek-v3-671b"],
                           STANDARD_SHAPES["train_4k"])
    assert big.pp > small.pp
    # capacity forces the 671B model to multiple stages (paper's wall)
    assert big.pp >= 4


def test_planner_duplication_on_small_models():
    """Small models replicate stages — the paper's weight-duplication
    lever at pod scale."""
    plan = plan_parallelism(ARCHS["mamba2-780m"],
                            STANDARD_SHAPES["train_4k"])
    assert plan.stages[0].dup >= 32

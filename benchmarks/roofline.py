"""Roofline table (deliverable g): per (arch x shape x mesh) terms from
the dry-run cache (``results/dryrun.json``).

Reports the three terms in seconds, the dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs (useful-compute fraction), and per-chip memory.

``--smoke`` runs a different job: a deterministic machine-model smoke
table — analytic and trace cycles for the golden workloads on the
default chip — printed in a fixed format, written to
``results/roofline_smoke.json``, and **compared against the committed
golden** (``benchmarks/roofline_smoke_golden.json``).  Any change to
the shared machine model (:mod:`repro.core.machine`) that shifts
reported cycles fails the CI job until the golden is regenerated with
``--update-golden`` — i.e. cycle drift requires a reviewed diff.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

DRYRUN = os.environ.get("DRYRUN_JSON", "results/dryrun.json")

SMOKE_WORKLOADS = (
    ("tiny_cnn", {}),
    ("resnet18", {"res": 112}),
    # dynamic-weight attention: analytic/trace weight-source costing
    ("transformer", {"n_layers": 1, "d_model": 128, "n_heads": 4,
                     "seq": 16, "vocab": 64}),
)
SMOKE_STRATEGIES = ("generic", "dp")
SMOKE_GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "roofline_smoke_golden.json")


def smoke_rows(batch: int = 4) -> List[Dict]:
    from repro import flow
    from repro.core.arch import default_chip
    from repro.core.mapping import CostParams

    chip = default_chip()
    rows: List[Dict] = []
    for model, kw in SMOKE_WORKLOADS:
        for strategy in SMOKE_STRATEGIES:
            art = flow.compile(
                model, chip,
                flow.CompileOptions(strategy=strategy,
                                    params=CostParams(batch=batch),
                                    workload_kw=kw or None))
            analytic = art.evaluate("analytic")
            trace = art.evaluate("trace")
            rows.append({
                "model": model, "kw": kw, "strategy": strategy,
                "batch": batch,
                "analytic_cycles": round(analytic.cycles, 1),
                "trace_cycles": round(trace.cycles, 1),
                "analytic_energy_nj": round(analytic.energy_total, 1),
                "n_stages": art.partition.n_stages,
            })
    return rows


def smoke_report(rows: List[Dict], out_json: Optional[str] = None) -> str:
    from repro.core.arch import default_chip
    out = ["== machine-model smoke (default chip) ==",
           default_chip().machine().describe(),
           f"{'model':16s} {'strategy':8s} {'stages':>6s} "
           f"{'analytic':>14s} {'trace':>14s} {'trace/ana':>9s}"]
    for r in rows:
        ratio = r["trace_cycles"] / max(r["analytic_cycles"], 1e-9)
        out.append(
            f"{r['model']:16s} {r['strategy']:8s} {r['n_stages']:6d} "
            f"{r['analytic_cycles']:14.0f} {r['trace_cycles']:14.0f} "
            f"{ratio:9.2f}")
    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1, sort_keys=True)
        out.append(f"wrote {out_json}")
    return "\n".join(out)


def smoke_drift(rows: List[Dict],
                golden_path: str = SMOKE_GOLDEN) -> List[str]:
    """Mismatches against the committed golden table (empty = clean)."""
    try:
        with open(golden_path) as f:
            golden = json.load(f)
    except FileNotFoundError:
        return [f"golden file {golden_path} missing "
                f"(regenerate with --update-golden)"]
    drift = []
    key = lambda r: (r["model"], r["strategy"])  # noqa: E731
    grows = {key(r): r for r in golden}
    for r in rows:
        g = grows.pop(key(r), None)
        if g is None:
            drift.append(f"{key(r)}: not in golden")
            continue
        for fld in ("analytic_cycles", "trace_cycles", "n_stages"):
            if r[fld] != g[fld]:
                drift.append(f"{key(r)}.{fld}: {g[fld]} -> {r[fld]}")
    drift.extend(f"{k}: only in golden" for k in grows)
    return drift


def load(path: str = DRYRUN) -> Dict:
    with open(path) as f:
        return json.load(f)


def rows(data: Optional[Dict] = None, mesh: str = "1pod") -> List[Dict]:
    data = data or load()
    out = []
    for key, rec in sorted(data.items()):
        if not key.endswith(f"|{mesh}"):
            continue
        if rec.get("status") != "ok":
            out.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                        "status": rec.get("status"),
                        "reason": rec.get("reason",
                                          rec.get("error", ""))[:60]})
            continue
        r = rec["roofline"]
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_frac": rec.get("useful_flops_frac"),
            "live_gib": rec.get("memory", {}).get("live_gib"),
            "fits": rec.get("memory", {}).get("fits_16g"),
        })
    return out


def report(mesh: str = "1pod") -> str:
    out = [f"== roofline ({mesh}) ==",
           "arch                     shape        compute_s  memory_s  "
           "collect_s dom         useful  GiB/chip"]
    for r in rows(mesh=mesh):
        if r.get("status") != "ok":
            out.append(f"{r.get('arch', '?'):24s} {r.get('shape', '?'):12s}"
                       f" [{r.get('status')}] {r.get('reason', '')}")
            continue
        uf = f"{r['useful_frac']:.2f}" if r["useful_frac"] else "  - "
        mem = f"{r['live_gib']:.1f}" if r["live_gib"] is not None else "-"
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{r['compute_s']:9.3g} {r['memory_s']:9.3g} "
            f"{r['collective_s']:9.3g} {r['dominant']:11s} {uf:>6s} "
            f"{mem:>7s}")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="machine-model cycles smoke table (CI gate)")
    ap.add_argument("--json", default="results/roofline_smoke.json",
                    help="smoke output path ('' to skip writing)")
    ap.add_argument("--update-golden", action="store_true",
                    help="rewrite benchmarks/roofline_smoke_golden.json "
                         "after an intentional machine-model change")
    args = ap.parse_args()
    if args.smoke:
        rows = smoke_rows()
        print(smoke_report(rows, args.json or None))
        if args.update_golden:
            with open(SMOKE_GOLDEN, "w") as f:
                json.dump(rows, f, indent=1, sort_keys=True)
            print(f"golden updated: {SMOKE_GOLDEN}")
            sys.exit(0)
        drift = smoke_drift(rows)
        if drift:
            print("MACHINE-MODEL DRIFT vs committed golden:")
            for d in drift:
                print(f"  {d}")
            print("if intentional, regenerate with "
                  "`python -m benchmarks.roofline --smoke "
                  "--update-golden` and commit the diff")
            sys.exit(1)
        print("golden: clean")
        sys.exit(0)
    print(report("1pod"))
    print()
    print(report("2pod"))

"""Roofline table (deliverable g): per (arch x shape x mesh) terms from
the dry-run cache (``results/dryrun.json``).

Reports the three terms in seconds, the dominant bottleneck,
MODEL_FLOPS / HLO_FLOPs (useful-compute fraction), and per-chip memory.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

DRYRUN = os.environ.get("DRYRUN_JSON", "results/dryrun.json")


def load(path: str = DRYRUN) -> Dict:
    with open(path) as f:
        return json.load(f)


def rows(data: Optional[Dict] = None, mesh: str = "1pod") -> List[Dict]:
    data = data or load()
    out = []
    for key, rec in sorted(data.items()):
        if not key.endswith(f"|{mesh}"):
            continue
        if rec.get("status") != "ok":
            out.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                        "status": rec.get("status"),
                        "reason": rec.get("reason",
                                          rec.get("error", ""))[:60]})
            continue
        r = rec["roofline"]
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_frac": rec.get("useful_flops_frac"),
            "live_gib": rec.get("memory", {}).get("live_gib"),
            "fits": rec.get("memory", {}).get("fits_16g"),
        })
    return out


def report(mesh: str = "1pod") -> str:
    out = [f"== roofline ({mesh}) ==",
           "arch                     shape        compute_s  memory_s  "
           "collect_s dom         useful  GiB/chip"]
    for r in rows(mesh=mesh):
        if r.get("status") != "ok":
            out.append(f"{r.get('arch', '?'):24s} {r.get('shape', '?'):12s}"
                       f" [{r.get('status')}] {r.get('reason', '')}")
            continue
        uf = f"{r['useful_frac']:.2f}" if r["useful_frac"] else "  - "
        mem = f"{r['live_gib']:.1f}" if r["live_gib"] is not None else "-"
        out.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{r['compute_s']:9.3g} {r['memory_s']:9.3g} "
            f"{r['collective_s']:9.3g} {r['dominant']:11s} {uf:>6s} "
            f"{mem:>7s}")
    return "\n".join(out)


if __name__ == "__main__":
    print(report("1pod"))
    print()
    print(report("2pod"))

"""Simulator performance trajectory: committed wall-time + cycles.

Measures the fidelity ladder on the golden workloads (tiny_cnn and
resnet18@112, batch 4, default chip) and records, per workload:

* cycles for analytic / trace / perf (and func where the model is
  functionally valid — resnet18@112 overflows local-memory segments on
  the default chip, so only its timing fidelities run);
* wall seconds for analytic, trace, the perf simulator on all three
  engines (``vector`` = pre-decoded numpy replay, ``scalar`` =
  interpreter, ``jax`` = jitted XLA stage engine), plus the vector
  engine's *cold* cost (decode tables stripped, so pack + decode
  + replay — the price codegen normally pays when it ships the tables);
* the vector-vs-scalar speedup per workload and its geomean;
* the *fleet* section: a 256-point unit-latency DSE sweep (one compiled
  program, ``explore.FleetEvaluator`` vmapped batching) against the
  pool-parallel per-point baseline — the batched evaluator must stay
  >= ``FLEET_MIN_SPEEDUP`` x faster;
* the *func_pallas* section: the Pallas bit-serial oracle backend
  validated bit-exact against the numpy oracle at resnet18@224.

Wall measurement protocol: engines are interleaved and the min over
``--reps`` repeats is kept, so CPU-share throttling hits both engines
alike and the committed *speedups* stay machine-comparable even though
absolute seconds are not.

The committed golden is ``BENCH_simulator.json`` at the repo root — the
perf trajectory tracked across PRs.  ``--smoke`` re-measures and fails
when cycles drift at all (machine-model/codegen change: regenerate with
``--update-golden`` and commit the diff) or when the measured speedup
falls more than 20% below the committed one AND below the absolute
``ABS_MIN_SPEEDUP`` floor — the same-machine ratio is stable, but a
different CPU/numpy build legitimately shifts it, so only missing both
bars indicates a real wall-time regression in the vectorized engine.

    PYTHONPATH=src python -m benchmarks.bench_sim [--smoke]
        [--update-golden] [--reps N] [--json PATH]
        [--engine {all,scalar,vector,jax}]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
import warnings
from typing import Dict, List, Optional

import numpy as np

GOLDEN_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_simulator.json")

# the golden workloads: (model, workload_kw, strategy, func-valid)
WORKLOADS = (
    ("tiny_cnn", {}, "dp", True),
    ("tiny_cnn", {}, "generic", True),
    ("resnet18", {"res": 112}, "dp", False),
    ("resnet18", {"res": 112}, "generic", False),
    # dynamic-weight attention (weight-source abstraction): guards the
    # transformer lowering path against regressing to compile errors
    ("transformer", {"n_layers": 1, "d_model": 128, "n_heads": 4,
                     "seq": 16, "vocab": 64}, "dp", True),
)
BATCH = 4
# fail --smoke when the measured speedup drops below this fraction of
# the committed golden's (the ">20% wall regression" gate).  The
# vector/scalar ratio is stable on ONE machine (engines are timed
# interleaved) but legitimately varies across CPUs/numpy builds, so a
# machine whose healthy ratio clears ABS_MIN_SPEEDUP passes even when
# it cannot reproduce the committed golden's ratio — only a genuine
# engine regression fails both bars.
SPEEDUP_TOLERANCE = 0.8
ABS_MIN_SPEEDUP = 4.0
# the vmapped fleet evaluator must beat the pool-parallel per-point
# baseline by at least this factor on the 256-point timing sweep.  The
# fleet's cost is one XLA compile + ~3ms/point of replay while the
# baseline pays a full compile+simulate pipeline per point, so the
# ratio *grows* with sweep size; the smoke gate normalizes the
# baseline to aggregate CPU cost (wall x pool width) so a many-core CI
# runner is judged on compute spent, not on how wide its pool is.
FLEET_MIN_SPEEDUP = 3.0
FLEET_POINTS = 256


def _strip_tables(model) -> None:
    """Drop the decode tables codegen attached (cold-start measurement)."""
    for sp in model.stages:
        for p in sp.programs.values():
            if hasattr(p, "_packed"):
                del p._packed


def _min_wall(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_rows(reps: int = 3) -> List[Dict]:
    from repro import flow
    from repro.core.arch import default_chip
    from repro.core.mapping import CostParams
    from repro.core.simulator import Simulator

    chip = default_chip()
    rows: List[Dict] = []
    for model, kw, strategy, func_ok in WORKLOADS:
        t0 = time.perf_counter()
        art = flow.compile(
            model, chip,
            flow.CompileOptions(strategy=strategy,
                                params=CostParams(batch=BATCH),
                                workload_kw=kw or None))
        ana = art.evaluate("analytic")
        tr = art.evaluate("trace")
        cm = art.ensure_model()      # codegen + decode tables
        compile_s = time.perf_counter() - t0

        vec_sim = Simulator(chip, cm.isa, engine="vector")
        scal_sim = Simulator(chip, cm.isa, engine="scalar")
        jax_sim = Simulator(chip, cm.isa, engine="jax")
        vec = vec_sim.run_model(cm)           # warm + correctness ref
        scal = scal_sim.run_model(cm)
        jx = jax_sim.run_model(cm)            # warm (jit compiles here)
        for name, rep in (("vectorized", vec), ("jax", jx)):
            if (rep.cycles != scal.cycles or rep.events != scal.events
                    or rep.unit_busy != scal.unit_busy
                    or rep.instrs != scal.instrs):
                raise AssertionError(
                    f"{model}/{strategy}: {name} engine diverged from "
                    f"the scalar interpreter (cycles {rep.cycles} vs "
                    f"{scal.cycles})")

        # interleaved min-of-reps: throttling hits all engines alike
        wall_v, wall_s, wall_j = (float("inf"),) * 3
        for _ in range(reps):
            t0 = time.perf_counter()
            vec_sim.run_model(cm)
            wall_v = min(wall_v, time.perf_counter() - t0)
            t0 = time.perf_counter()
            scal_sim.run_model(cm)
            wall_s = min(wall_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax_sim.run_model(cm)
            wall_j = min(wall_j, time.perf_counter() - t0)

        def cold() -> None:
            _strip_tables(cm)
            Simulator(chip, cm.isa, engine="vector").run_model(cm)

        wall_cold = _min_wall(cold, max(1, reps - 1))
        cm2 = art.ensure_model()     # re-attach tables for later users
        for sp in cm2.stages:
            for p in sp.programs.values():
                p.pack(cm2.isa)

        row = {
            "workload": model, "kw": kw, "strategy": strategy,
            "batch": BATCH, "instrs": int(vec.instrs),
            "compile_s": round(compile_s, 3),
            "cycles": {
                "analytic": round(ana.cycles, 1),
                "trace": round(tr.cycles, 1),
                "perf": vec.cycles,
            },
            "wall_s": {
                "analytic": round(ana.wall_s, 5),
                "trace": round(tr.wall_s, 5),
                "perf_vector": round(wall_v, 5),
                "perf_vector_cold": round(wall_cold, 5),
                "perf_scalar": round(wall_s, 5),
                "perf_jax": round(wall_j, 5),
            },
            "speedup": round(wall_s / wall_v, 2),
            "speedup_cold": round(wall_s / wall_cold, 2),
            "speedup_jax": round(wall_s / wall_j, 2),
        }
        if func_ok:
            img = np.zeros(cm.layout.size, dtype=np.int8)
            t0 = time.perf_counter()
            fn = Simulator(chip, cm.isa, mode="func").run_model(
                cm, gmem_image=img)
            row["wall_s"]["func"] = round(time.perf_counter() - t0, 5)
            row["cycles"]["func"] = fn.cycles
        rows.append(row)
    return rows


def profile_engine(engine: str, reps: int = 3) -> List[Dict]:
    """Time one perf engine alone on the golden workloads (the
    ``--engine`` path — a profiling aid, no golden interplay)."""
    from repro import flow
    from repro.core.arch import default_chip
    from repro.core.mapping import CostParams
    from repro.core.simulator import Simulator

    chip = default_chip()
    rows = []
    for model, kw, strategy, _func_ok in WORKLOADS:
        art = flow.compile(
            model, chip,
            flow.CompileOptions(strategy=strategy,
                                params=CostParams(batch=BATCH),
                                workload_kw=kw or None))
        cm = art.ensure_model()
        sim = Simulator(chip, cm.isa, engine=engine)
        rep = sim.run_model(cm)              # warm
        wall = _min_wall(lambda: sim.run_model(cm), reps)
        rows.append({"workload": model, "kw": kw, "strategy": strategy,
                     "engine": engine, "cycles": rep.cycles,
                     "wall_s": round(wall, 5)})
    return rows


def bench_fleet(n_points: int = FLEET_POINTS, reps: int = 1) -> Dict:
    """256-point unit-latency sweep at simulate fidelity: the vmapped
    fleet evaluator (one compile, batched XLA decode) vs the
    pool-parallel per-point pipeline.

    The baseline compiles each timing point's own chip, so its results
    can legitimately diverge from the fleet's pinned-program semantics
    on points where a timing constant steers the partitioner — the
    sweep-level contract checked here is the all-defaults point, whose
    canonical chip IS its own chip.
    """
    from repro.core.mapping import CostParams
    from repro.explore import ExplorationEngine, timing_space

    sp = timing_space(scalar_alu=(1, 2, 3, 4), router=(1, 2, 3, 4))
    pts = list(sp.points())[:n_points]
    params = CostParams(batch=BATCH)
    pool = os.cpu_count() or 1

    jx = ExplorationEngine("tiny_cnn", params=params, engine="jax")
    wall_fleet = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jrecs = jx.evaluate(pts, fidelity="simulate")
        wall_fleet = min(wall_fleet, time.perf_counter() - t0)
    assert all(r.ok for r in jrecs), [r.error for r in jrecs if not r.ok]

    base = ExplorationEngine("tiny_cnn", params=params, pool=pool,
                             engine="auto")
    t0 = time.perf_counter()
    brecs = base.evaluate(pts, fidelity="simulate")
    wall_pool = time.perf_counter() - t0

    defaults = next(i for i, p in enumerate(pts)
                    if (p.scalar_alu_latency, p.vector_alu_latency,
                        p.weight_load_rows_per_cycle,
                        p.router_latency) == (1, 1, 1, 2))
    if jrecs[defaults].cycles != brecs[defaults].cycles:
        raise AssertionError(
            f"fleet diverged from the per-point baseline on the "
            f"all-defaults point: {jrecs[defaults].cycles} vs "
            f"{brecs[defaults].cycles}")
    return {
        "workload": "tiny_cnn", "batch": BATCH, "points": len(pts),
        "pool": pool,
        "wall_s": {"fleet_jax": round(wall_fleet, 3),
                   "pool_baseline": round(wall_pool, 3)},
        "speedup": round(wall_pool / wall_fleet, 2),
    }


def bench_func_pallas(res: int = 224) -> Dict:
    """resnet18@``res`` through the ``func:pallas`` oracle backend —
    every MVM on the Pallas bit-serial kernel, asserted bit-exact
    against the pure-numpy oracle (check=True raises on mismatch)."""
    from repro import flow
    from repro.core.arch import default_chip

    art = flow.compile("resnet18", default_chip(), flow.CompileOptions(
        strategy="dp", batch=1, workload_kw={"res": res},
        fidelity="analytic"))
    rep = art.evaluate("func:pallas")
    return {"workload": "resnet18", "res": res, "batch": 1,
            "groups": len(rep.outputs), "bit_exact": True,
            "wall_s": round(rep.wall_s, 2)}


def _geomean(xs: List[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def to_doc(rows: List[Dict], fleet: Optional[Dict] = None,
           func_pallas: Optional[Dict] = None) -> Dict:
    doc = {
        "schema": 2,
        "chip": "default",
        "note": ("speedup = perf_scalar / perf_vector wall (speedup_jax "
                 "likewise), interleaved min-of-reps; *_cold includes "
                 "pack+decode (normally paid once at codegen); fleet = "
                 "vmapped batched DSE sweep vs pool-parallel per-point "
                 "baseline"),
        "rows": rows,
        "geomean_speedup": round(_geomean([r["speedup"] for r in rows]),
                                 2),
        "geomean_speedup_cold": round(
            _geomean([r["speedup_cold"] for r in rows]), 2),
        "geomean_speedup_jax": round(
            _geomean([r["speedup_jax"] for r in rows]), 2),
    }
    if fleet is not None:
        doc["fleet"] = fleet
    if func_pallas is not None:
        doc["func_pallas"] = func_pallas
    return doc


def report(doc: Dict) -> str:
    out = ["== simulator bench (default chip, batch 4) ==",
           f"{'workload':20s} {'strategy':8s} {'instrs':>8s} "
           f"{'perf cycles':>12s} {'scalar':>9s} {'vector':>9s} "
           f"{'cold':>9s} {'speedup':>8s}"]
    for r in doc["rows"]:
        w = r["wall_s"]
        name = r["workload"] + "".join(f"@{k}={v}"
                                       for k, v in sorted(r["kw"].items()))
        out.append(
            f"{name:20s} {r['strategy']:8s} {r['instrs']:8d} "
            f"{r['cycles']['perf']:12.0f} {w['perf_scalar']*1e3:8.1f}m "
            f"{w['perf_vector']*1e3:8.2f}m "
            f"{w['perf_vector_cold']*1e3:8.1f}m {r['speedup']:7.1f}x")
    out.append(f"geomean speedup: {doc['geomean_speedup']:.2f}x "
               f"(cold {doc['geomean_speedup_cold']:.2f}x, "
               f"jax {doc.get('geomean_speedup_jax', 0):.2f}x)")
    fl = doc.get("fleet")
    if fl:
        w = fl["wall_s"]
        out.append(
            f"fleet sweep ({fl['points']} timing points, "
            f"{fl['workload']}): vmapped {w['fleet_jax']:.2f}s vs "
            f"pool[{fl['pool']}] {w['pool_baseline']:.2f}s = "
            f"{fl['speedup']:.1f}x")
    fp = doc.get("func_pallas")
    if fp:
        out.append(
            f"func:pallas {fp['workload']}@{fp['res']}: "
            f"{fp['groups']} groups bit-exact vs numpy oracle in "
            f"{fp['wall_s']:.1f}s")
    return "\n".join(out)


def smoke_drift(doc: Dict, golden: Dict) -> List[str]:
    """Failures vs the committed golden (empty = clean)."""
    drift: List[str] = []
    key = lambda r: (r["workload"], json.dumps(r["kw"], sort_keys=True),
                     r["strategy"])                         # noqa: E731
    grows = {key(r): r for r in golden.get("rows", [])}
    for r in doc["rows"]:
        g = grows.pop(key(r), None)
        if g is None:
            drift.append(f"{key(r)}: not in golden")
            continue
        for fid in sorted(set(r["cycles"]) | set(g["cycles"])):
            cyc = r["cycles"].get(fid)
            gc = g["cycles"].get(fid)
            if cyc is None or gc is None:
                drift.append(f"{key(r)}.cycles.{fid}: "
                             f"{'missing' if cyc is None else 'new'} "
                             f"vs golden")
            elif cyc != gc:
                drift.append(f"{key(r)}.cycles.{fid}: {gc} -> {cyc}")
        if r["instrs"] != g["instrs"]:
            drift.append(f"{key(r)}.instrs: {g['instrs']} -> "
                         f"{r['instrs']}")
        floor = g["speedup"] * SPEEDUP_TOLERANCE
        # the absolute floor halves for rows whose committed ratio is
        # itself small (short-program rows — e.g. the transformer block
        # — measure noisier, and a 4x floor leaves them no slack)
        abs_floor = min(ABS_MIN_SPEEDUP, 0.5 * g["speedup"])
        if r["speedup"] < floor and r["speedup"] < abs_floor:
            drift.append(
                f"{key(r)}.speedup: {r['speedup']}x < {floor:.1f}x "
                f"(>20% wall-time regression vs golden "
                f"{g['speedup']}x) and below the absolute "
                f"{abs_floor:.1f}x floor")
    drift.extend(f"{k}: only in golden" for k in grows)
    fl = doc.get("fleet")
    gfl = golden.get("fleet")
    if fl is None:
        if gfl is not None:
            drift.append("fleet: section missing (golden has one)")
    else:
        # pool-normalized: a wider pool legitimately shrinks the wall
        # ratio, so gate on the baseline's aggregate CPU cost
        # (wall x pool width) -- equal to the wall ratio on the
        # single-core machine the golden is committed from
        norm = fl["speedup"] * fl.get("pool", 1)
        gnorm = ((gfl["speedup"] * gfl.get("pool", 1))
                 if gfl else FLEET_MIN_SPEEDUP)
        if norm < FLEET_MIN_SPEEDUP and norm < SPEEDUP_TOLERANCE * gnorm:
            drift.append(
                f"fleet.speedup: {fl['speedup']}x over a "
                f"{fl.get('pool', 1)}-wide pool "
                f"({norm:.2f}x CPU-normalized) < the "
                f"{FLEET_MIN_SPEEDUP}x floor and >20% below the "
                f"golden's {gnorm:.2f}x (vmapped batched evaluator "
                f"vs pool-parallel baseline, {fl['points']} points)")
    fp = doc.get("func_pallas")
    if fp is None and golden.get("func_pallas") is not None:
        drift.append("func_pallas: section missing (golden has one)")
    return drift


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="gate against the committed golden (CI job)")
    ap.add_argument("--update-golden", action="store_true",
                    help=f"rewrite {GOLDEN_PATH}")
    ap.add_argument("--reps", type=int, default=None,
                    help="timing repeats per engine (default: 3, "
                         "smoke: 2)")
    ap.add_argument("--json", default="results/bench_simulator.json",
                    help="also write the measured doc here "
                         "('' to skip)")
    ap.add_argument("--engine",
                    choices=("all", "scalar", "vector", "jax"),
                    default="all",
                    help="profile one perf engine only (skips the "
                         "golden/fleet/func sections)")
    args = ap.parse_args(argv)
    reps = args.reps or (2 if args.smoke else 3)

    if args.engine != "all":
        if args.smoke or args.update_golden:
            raise SystemExit("--engine profiles one engine only; it "
                             "cannot be combined with --smoke / "
                             "--update-golden")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            rows = profile_engine(args.engine, reps=reps)
        for r in rows:
            name = r["workload"] + "".join(
                f"@{k}={v}" for k, v in sorted(r["kw"].items()))
            print(f"{name:20s} {r['strategy']:8s} "
                  f"[{r['engine']}] {r['cycles']:12.0f} cycles  "
                  f"{r['wall_s'] * 1e3:8.2f}ms")
        return 0

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        doc = to_doc(bench_rows(reps=reps),
                     fleet=bench_fleet(reps=1),
                     func_pallas=bench_func_pallas())
    print(report(doc))
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.update_golden:
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"golden updated: {GOLDEN_PATH}")
        return 0
    if args.smoke:
        try:
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        except FileNotFoundError:
            print(f"golden {GOLDEN_PATH} missing "
                  f"(generate with --update-golden)")
            return 1
        drift = smoke_drift(doc, golden)
        if drift:
            print("SIMULATOR BENCH DRIFT vs committed golden:")
            for d in drift:
                print(f"  {d}")
            print("if the cycle change is intentional, regenerate with "
                  "`python -m benchmarks.bench_sim --update-golden` "
                  "and commit the diff")
            return 1
        print("golden: clean "
              f"(committed geomean {golden['geomean_speedup']}x, "
              f"measured {doc['geomean_speedup']}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Simulator performance trajectory: committed wall-time + cycles.

Measures the fidelity ladder on the golden workloads (tiny_cnn and
resnet18@112, batch 4, default chip) and records, per workload:

* cycles for analytic / trace / perf (and func where the model is
  functionally valid — resnet18@112 overflows local-memory segments on
  the default chip, so only its timing fidelities run);
* wall seconds for analytic, trace, the perf simulator on both engines
  (``vector`` = pre-decoded replay, ``scalar`` = interpreter), plus the
  vector engine's *cold* cost (decode tables stripped, so pack + decode
  + replay — the price codegen normally pays when it ships the tables);
* the vector-vs-scalar speedup per workload and its geomean.

Wall measurement protocol: engines are interleaved and the min over
``--reps`` repeats is kept, so CPU-share throttling hits both engines
alike and the committed *speedups* stay machine-comparable even though
absolute seconds are not.

The committed golden is ``BENCH_simulator.json`` at the repo root — the
perf trajectory tracked across PRs.  ``--smoke`` re-measures and fails
when cycles drift at all (machine-model/codegen change: regenerate with
``--update-golden`` and commit the diff) or when the measured speedup
falls more than 20% below the committed one AND below the absolute
``ABS_MIN_SPEEDUP`` floor — the same-machine ratio is stable, but a
different CPU/numpy build legitimately shifts it, so only missing both
bars indicates a real wall-time regression in the vectorized engine.

    PYTHONPATH=src python -m benchmarks.bench_sim [--smoke]
        [--update-golden] [--reps N] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
import warnings
from typing import Dict, List, Optional

import numpy as np

GOLDEN_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_simulator.json")

# the golden workloads: (model, workload_kw, strategy, func-valid)
WORKLOADS = (
    ("tiny_cnn", {}, "dp", True),
    ("tiny_cnn", {}, "generic", True),
    ("resnet18", {"res": 112}, "dp", False),
    ("resnet18", {"res": 112}, "generic", False),
    # dynamic-weight attention (weight-source abstraction): guards the
    # transformer lowering path against regressing to compile errors
    ("transformer", {"n_layers": 1, "d_model": 128, "n_heads": 4,
                     "seq": 16, "vocab": 64}, "dp", True),
)
BATCH = 4
# fail --smoke when the measured speedup drops below this fraction of
# the committed golden's (the ">20% wall regression" gate).  The
# vector/scalar ratio is stable on ONE machine (engines are timed
# interleaved) but legitimately varies across CPUs/numpy builds, so a
# machine whose healthy ratio clears ABS_MIN_SPEEDUP passes even when
# it cannot reproduce the committed golden's ratio — only a genuine
# engine regression fails both bars.
SPEEDUP_TOLERANCE = 0.8
ABS_MIN_SPEEDUP = 4.0


def _strip_tables(model) -> None:
    """Drop the decode tables codegen attached (cold-start measurement)."""
    for sp in model.stages:
        for p in sp.programs.values():
            if hasattr(p, "_packed"):
                del p._packed


def _min_wall(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_rows(reps: int = 3) -> List[Dict]:
    from repro import flow
    from repro.core.arch import default_chip
    from repro.core.mapping import CostParams
    from repro.core.simulator import Simulator

    chip = default_chip()
    rows: List[Dict] = []
    for model, kw, strategy, func_ok in WORKLOADS:
        t0 = time.perf_counter()
        art = flow.compile(
            model, chip,
            flow.CompileOptions(strategy=strategy,
                                params=CostParams(batch=BATCH),
                                workload_kw=kw or None))
        ana = art.evaluate("analytic")
        tr = art.evaluate("trace")
        cm = art.ensure_model()      # codegen + decode tables
        compile_s = time.perf_counter() - t0

        vec_sim = Simulator(chip, cm.isa, engine="vector")
        scal_sim = Simulator(chip, cm.isa, engine="scalar")
        vec = vec_sim.run_model(cm)           # warm + correctness ref
        scal = scal_sim.run_model(cm)
        if (vec.cycles != scal.cycles or vec.events != scal.events
                or vec.unit_busy != scal.unit_busy
                or vec.instrs != scal.instrs):
            raise AssertionError(
                f"{model}/{strategy}: vectorized engine diverged from "
                f"the scalar interpreter (cycles {vec.cycles} vs "
                f"{scal.cycles})")

        # interleaved min-of-reps: throttling hits both engines alike
        wall_v, wall_s = float("inf"), float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            vec_sim.run_model(cm)
            wall_v = min(wall_v, time.perf_counter() - t0)
            t0 = time.perf_counter()
            scal_sim.run_model(cm)
            wall_s = min(wall_s, time.perf_counter() - t0)

        def cold() -> None:
            _strip_tables(cm)
            Simulator(chip, cm.isa, engine="vector").run_model(cm)

        wall_cold = _min_wall(cold, max(1, reps - 1))
        cm2 = art.ensure_model()     # re-attach tables for later users
        for sp in cm2.stages:
            for p in sp.programs.values():
                p.pack(cm2.isa)

        row = {
            "workload": model, "kw": kw, "strategy": strategy,
            "batch": BATCH, "instrs": int(vec.instrs),
            "compile_s": round(compile_s, 3),
            "cycles": {
                "analytic": round(ana.cycles, 1),
                "trace": round(tr.cycles, 1),
                "perf": vec.cycles,
            },
            "wall_s": {
                "analytic": round(ana.wall_s, 5),
                "trace": round(tr.wall_s, 5),
                "perf_vector": round(wall_v, 5),
                "perf_vector_cold": round(wall_cold, 5),
                "perf_scalar": round(wall_s, 5),
            },
            "speedup": round(wall_s / wall_v, 2),
            "speedup_cold": round(wall_s / wall_cold, 2),
        }
        if func_ok:
            img = np.zeros(cm.layout.size, dtype=np.int8)
            t0 = time.perf_counter()
            fn = Simulator(chip, cm.isa, mode="func").run_model(
                cm, gmem_image=img)
            row["wall_s"]["func"] = round(time.perf_counter() - t0, 5)
            row["cycles"]["func"] = fn.cycles
        rows.append(row)
    return rows


def _geomean(xs: List[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def to_doc(rows: List[Dict]) -> Dict:
    return {
        "schema": 1,
        "chip": "default",
        "note": ("speedup = perf_scalar / perf_vector wall, interleaved "
                 "min-of-reps; *_cold includes pack+decode (normally "
                 "paid once at codegen)"),
        "rows": rows,
        "geomean_speedup": round(_geomean([r["speedup"] for r in rows]),
                                 2),
        "geomean_speedup_cold": round(
            _geomean([r["speedup_cold"] for r in rows]), 2),
    }


def report(doc: Dict) -> str:
    out = ["== simulator bench (default chip, batch 4) ==",
           f"{'workload':20s} {'strategy':8s} {'instrs':>8s} "
           f"{'perf cycles':>12s} {'scalar':>9s} {'vector':>9s} "
           f"{'cold':>9s} {'speedup':>8s}"]
    for r in doc["rows"]:
        w = r["wall_s"]
        name = r["workload"] + "".join(f"@{k}={v}"
                                       for k, v in sorted(r["kw"].items()))
        out.append(
            f"{name:20s} {r['strategy']:8s} {r['instrs']:8d} "
            f"{r['cycles']['perf']:12.0f} {w['perf_scalar']*1e3:8.1f}m "
            f"{w['perf_vector']*1e3:8.2f}m "
            f"{w['perf_vector_cold']*1e3:8.1f}m {r['speedup']:7.1f}x")
    out.append(f"geomean speedup: {doc['geomean_speedup']:.2f}x "
               f"(cold {doc['geomean_speedup_cold']:.2f}x)")
    return "\n".join(out)


def smoke_drift(doc: Dict, golden: Dict) -> List[str]:
    """Failures vs the committed golden (empty = clean)."""
    drift: List[str] = []
    key = lambda r: (r["workload"], json.dumps(r["kw"], sort_keys=True),
                     r["strategy"])                         # noqa: E731
    grows = {key(r): r for r in golden.get("rows", [])}
    for r in doc["rows"]:
        g = grows.pop(key(r), None)
        if g is None:
            drift.append(f"{key(r)}: not in golden")
            continue
        for fid in sorted(set(r["cycles"]) | set(g["cycles"])):
            cyc = r["cycles"].get(fid)
            gc = g["cycles"].get(fid)
            if cyc is None or gc is None:
                drift.append(f"{key(r)}.cycles.{fid}: "
                             f"{'missing' if cyc is None else 'new'} "
                             f"vs golden")
            elif cyc != gc:
                drift.append(f"{key(r)}.cycles.{fid}: {gc} -> {cyc}")
        if r["instrs"] != g["instrs"]:
            drift.append(f"{key(r)}.instrs: {g['instrs']} -> "
                         f"{r['instrs']}")
        floor = g["speedup"] * SPEEDUP_TOLERANCE
        # the absolute floor halves for rows whose committed ratio is
        # itself small (short-program rows — e.g. the transformer block
        # — measure noisier, and a 4x floor leaves them no slack)
        abs_floor = min(ABS_MIN_SPEEDUP, 0.5 * g["speedup"])
        if r["speedup"] < floor and r["speedup"] < abs_floor:
            drift.append(
                f"{key(r)}.speedup: {r['speedup']}x < {floor:.1f}x "
                f"(>20% wall-time regression vs golden "
                f"{g['speedup']}x) and below the absolute "
                f"{abs_floor:.1f}x floor")
    drift.extend(f"{k}: only in golden" for k in grows)
    return drift


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="gate against the committed golden (CI job)")
    ap.add_argument("--update-golden", action="store_true",
                    help=f"rewrite {GOLDEN_PATH}")
    ap.add_argument("--reps", type=int, default=None,
                    help="timing repeats per engine (default: 3, "
                         "smoke: 2)")
    ap.add_argument("--json", default="results/bench_simulator.json",
                    help="also write the measured doc here "
                         "('' to skip)")
    args = ap.parse_args(argv)
    reps = args.reps or (2 if args.smoke else 3)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        doc = to_doc(bench_rows(reps=reps))
    print(report(doc))
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.update_golden:
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"golden updated: {GOLDEN_PATH}")
        return 0
    if args.smoke:
        try:
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        except FileNotFoundError:
            print(f"golden {GOLDEN_PATH} missing "
                  f"(generate with --update-golden)")
            return 1
        drift = smoke_drift(doc, golden)
        if drift:
            print("SIMULATOR BENCH DRIFT vs committed golden:")
            for d in drift:
                print(f"  {d}")
            print("if the cycle change is intentional, regenerate with "
                  "`python -m benchmarks.bench_sim --update-golden` "
                  "and commit the diff")
            return 1
        print("golden: clean "
              f"(committed geomean {golden['geomean_speedup']}x, "
              f"measured {doc['geomean_speedup']}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

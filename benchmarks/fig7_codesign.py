"""Fig. 7 reproduction: the software/hardware co-design space —
strategies x {MG size, flit width} grids per model.

Claim to validate: compilation strategy can close (or invert) gaps
between hardware configurations — a DP-compiled small-MG chip can beat a
generically-compiled large-MG chip, which is the paper's argument for
integrated SW/HW exploration.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import workloads
from repro.core.dse import sweep_mg_flit
from repro.core.mapping import CostParams
from repro.core.partition import STRATEGIES

MODELS = ("resnet18", "efficientnetb0")
RES = 112


def run(simulate: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    for model in MODELS:
        cg = workloads.build(model, res=RES).condense()
        for strat in STRATEGIES:
            for pt in sweep_mg_flit(cg, strategy=strat,
                                    simulate=simulate,
                                    params=CostParams(batch=4)):
                rows.append(pt.row())
    return rows


def report(rows: List[Dict]) -> str:
    out = ["model            strategy  MG flit  thpt(sps)"]
    for r in rows:
        out.append(f"{r['model']:16s} {r['strategy']:9s} {r['mg']:2d} "
                   f"{r['flit']:4d} {r['throughput_sps']:9.1f}")
    # the co-design claim: best small-MG dp vs worst large-MG generic
    for model in MODELS:
        sub = [r for r in rows if r["model"] == model]
        dp_small = max(r["throughput_sps"] for r in sub
                       if r["strategy"] == "dp" and r["mg"] == 4)
        gen_big = max(r["throughput_sps"] for r in sub
                      if r["strategy"] == "generic" and r["mg"] == 16)
        verdict = "closes/inverts" if dp_small > gen_big else "narrows"
        out.append(f"-> {model}: dp@MG4 {dp_small:.1f} vs generic@MG16 "
                   f"{gen_big:.1f} sps ({verdict} the hw gap)")
    return "\n".join(out)


if __name__ == "__main__":
    print(report(run()))

"""Fig. 7 reproduction: the software/hardware co-design space —
strategies x {MG size, flit width} grids per model.

Claim to validate: compilation strategy can close (or invert) gaps
between hardware configurations — a DP-compiled small-MG chip can beat a
generically-compiled large-MG chip, which is the paper's argument for
integrated SW/HW exploration.

Runs on the ``repro.explore`` engine (pool + result cache, evaluating
through the :mod:`repro.flow` pass pipeline) and appends a
cycles-vs-energy Pareto frontier per model — the co-design trade-off
curve the serial seed driver could not produce.  The default fidelity
is ``trace`` (the calibratable middle rung of the ladder);
``--fidelity`` overrides, and ``--simulate`` stays as a legacy alias
for ``--fidelity simulate``.

    PYTHONPATH=src python -m benchmarks.fig7_codesign
        [--fidelity {analytic,trace,simulate}] [--calibration NAME]
        [--pool N] [--no-cache]
"""

from __future__ import annotations

import argparse
from typing import Dict, List, Optional

from repro.core.mapping import CostParams
from repro.core.partition import STRATEGIES
from repro.explore import (DesignPoint, EvalRecord, ExplorationEngine,
                           default_cache_dir, frontier_report,
                           mg_flit_space)
from repro.explore.space import SWEEP_FLIT, SWEEP_MG

MODELS = ("resnet18", "efficientnetb0")
RES = 112
DEFAULT_POOL = 8


def run(simulate: Optional[bool] = None, pool: Optional[int] = None,
        cache: bool = True, fidelity: str = "trace",
        calibration: Optional[str] = None) -> List[Dict]:
    if simulate is not None:            # legacy boolean knob
        fidelity = "simulate" if simulate else "analytic"
    pool = DEFAULT_POOL if pool is None else pool
    space = mg_flit_space(SWEEP_MG, SWEEP_FLIT, strategies=STRATEGIES)
    rows: List[Dict] = []
    for model in MODELS:
        eng = ExplorationEngine(model, res=RES,
                                params=CostParams(batch=4), pool=pool,
                                calibration=calibration,
                                cache=default_cache_dir() if cache
                                else None)
        recs = eng.sweep(space, fidelity=fidelity)
        rows.extend(r.row() for r in recs)
    return rows


def _rows_to_records(rows: List[Dict]) -> List[EvalRecord]:
    """Lift flat row dicts back into records (rows carry every point
    field plus the cycles/total-energy the frontier axes need)."""
    return [
        EvalRecord(
            point=DesignPoint(macros_per_group=r["mg"],
                              n_macro_groups=r["n_mg"],
                              n_cores=r["cores"],
                              flit_bytes=r["flit"],
                              local_mem_kb=r["lmem_kb"],
                              strategy=r["strategy"]),
            model=r["model"],
            fidelity=r.get("fidelity",
                           "simulate" if r["simulated"] else "analytic"),
            cycles=r["cycles"], throughput_sps=r["throughput_sps"],
            energy={"total": r["energy_total_mJ"] * 1e6},
            error=r.get("error"))
        for r in rows
    ]


def frontiers(rows: List[Dict]) -> str:
    """Cycles/energy Pareto frontier per model from the given rows."""
    recs = _rows_to_records(rows)
    out: List[str] = []
    for model in MODELS:
        sub = [r for r in recs if r.model == model]
        if not sub:
            continue
        out.append(f"Pareto frontier (cycles vs energy) — {model}:")
        out.append(frontier_report(sub, axes=("cycles", "energy")))
    return "\n".join(out)


def report(rows: List[Dict]) -> str:
    out = ["model            strategy  MG flit  thpt(sps)"]
    for r in rows:
        out.append(f"{r['model']:16s} {r['strategy']:9s} {r['mg']:2d} "
                   f"{r['flit']:4d} {r['throughput_sps']:9.1f}")
    # the co-design claim: best small-MG dp vs worst large-MG generic
    for model in MODELS:
        sub = [r for r in rows if r["model"] == model]
        dp_small = max(r["throughput_sps"] for r in sub
                       if r["strategy"] == "dp" and r["mg"] == 4)
        gen_big = max(r["throughput_sps"] for r in sub
                      if r["strategy"] == "generic" and r["mg"] == 16)
        verdict = "closes/inverts" if dp_small > gen_big else "narrows"
        out.append(f"-> {model}: dp@MG4 {dp_small:.1f} vs generic@MG16 "
                   f"{gen_big:.1f} sps ({verdict} the hw gap)")
    out.append(frontiers(rows))
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fidelity", default="trace",
                    choices=("analytic", "trace", "simulate"),
                    help="evaluation fidelity (default: trace)")
    ap.add_argument("--calibration", default=None,
                    help="named calibration preset for cheap fidelities "
                         "(results/calibrations/<name>.json)")
    ap.add_argument("--simulate", action="store_true",
                    help="legacy alias for --fidelity simulate")
    ap.add_argument("--pool", type=int, default=None,
                    help=f"worker processes (default {DEFAULT_POOL})")
    ap.add_argument("--no-cache", action="store_true",
                    help="bypass the on-disk result cache")
    args = ap.parse_args()
    print(report(run(pool=args.pool, cache=not args.no_cache,
                     fidelity=("simulate" if args.simulate
                               else args.fidelity),
                     calibration=args.calibration)))

"""Fig. 5 reproduction: normalized speed + energy of the three compilation
strategies across the four DNN benchmarks (cycle-accurate simulator).

Paper claims to validate (relative trends): the DP strategy dominates
both baselines — up to 2.8x speedup and 61.7% energy reduction — with
the largest wins on the compact models (MobileNetV2, EfficientNetB0),
where capacity-first partitioning leaves too few vacant cores for
opportunistic duplication.

Runs on the :mod:`repro.flow` pipeline: one ``compile`` per strategy,
scored at any rung of the fidelity ladder; the condense pass is shared
across strategies through the pipeline's pass-output cache.  The
default fidelity is ``trace`` (the calibratable middle rung — within
2x of perf cycles at a fraction of the cost); ``--fidelity simulate``
reproduces the paper's cycle-accurate numbers, ``--fidelity analytic``
is the fast screen.

    PYTHONPATH=src python -m benchmarks.fig5_compilation
        [--fidelity {analytic,trace,simulate}] [--calibration NAME]
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

from repro import flow
from repro.core import workloads
from repro.core.arch import default_chip
from repro.core.mapping import CostParams
from repro.core.partition import STRATEGIES
from repro.flow import CompileOptions

MODELS = ("resnet18", "vgg19", "mobilenetv2", "efficientnetb0")
RES = 112            # keep the cycle-accurate runs CPU-friendly
BATCH = 4


def run(simulate: Optional[bool] = None, fidelity: str = "trace",
        calibration: Optional[str] = None) -> List[Dict]:
    if simulate is not None:        # legacy boolean knob
        fidelity = "simulate" if simulate else "analytic"
    chip = default_chip()
    opts = CompileOptions(params=CostParams(batch=BATCH),
                          fidelity=fidelity, calibration=calibration)
    rows: List[Dict] = []
    for model in MODELS:
        cg = workloads.build(model, res=RES).condense()
        base = None
        for strat in STRATEGIES:
            t0 = time.time()
            art = flow.compile(cg, chip, opts, strategy=strat)
            rep = art.evaluate()
            cycles, energy = rep.cycles, rep.energy["total"]
            if strat == "generic":
                base = (cycles, energy)
            rows.append({
                "model": model, "strategy": strat,
                "cycles": cycles, "energy_nJ": energy,
                "speed_norm": base[0] / cycles,
                "energy_norm": energy / base[1],
                "n_stages": art.partition.n_stages,
                "wall_s": round(time.time() - t0, 1),
            })
    return rows


def report(rows: List[Dict]) -> str:
    out = ["model            strategy   speed(x)  energy(rel)  stages"]
    for r in rows:
        out.append(f"{r['model']:16s} {r['strategy']:9s} "
                   f"{r['speed_norm']:7.2f}  {r['energy_norm']:10.2f}  "
                   f"{r['n_stages']:5d}")
    dp = [r for r in rows if r["strategy"] == "dp"]
    best_speed = max(r["speed_norm"] for r in dp)
    best_energy = min(r["energy_norm"] for r in dp)
    out.append(f"-> max speedup {best_speed:.2f}x, max energy reduction "
               f"{100 * (1 - best_energy):.1f}% (paper: 2.8x / 61.7%)")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fidelity", default="trace",
                    choices=("analytic", "trace", "simulate"),
                    help="evaluation fidelity (default: trace)")
    ap.add_argument("--calibration", default=None,
                    help="named calibration preset to apply to cheap "
                         "fidelities (results/calibrations/<name>.json)")
    args = ap.parse_args()
    print(report(run(fidelity=args.fidelity,
                     calibration=args.calibration)))

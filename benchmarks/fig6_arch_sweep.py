"""Fig. 6 reproduction: energy breakdown + throughput across MG sizes
{4, 8, 16} and NoC flit widths {8, 16 B} for a compute-intensive model
(ResNet18) and a compact one (EfficientNetB0), generic mapping.

Trends to validate: ResNet18 throughput scales with MG size with
compute-dominated energy; EfficientNetB0 sees only modest gains while
data movement (NoC + gmem) grows toward the paper's ~55% share at small
MG / wide flit.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import workloads
from repro.core.dse import SWEEP_FLIT, SWEEP_MG, sweep_mg_flit
from repro.core.mapping import CostParams

MODELS = ("resnet18", "efficientnetb0")
RES = 112


def run(simulate: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    for model in MODELS:
        cg = workloads.build(model, res=RES).condense()
        for pt in sweep_mg_flit(cg, strategy="generic",
                                simulate=simulate,
                                params=CostParams(batch=4)):
            rows.append(pt.row())
    return rows


def report(rows: List[Dict]) -> str:
    out = ["model            MG flit  thpt(sps)  compute%  noc+gmem%  "
           "static%"]
    for r in rows:
        move = r["energy_noc_frac"] + r["energy_gmem_frac"] \
            + r["energy_weight_load_frac"]
        out.append(
            f"{r['model']:16s} {r['mg']:2d} {r['flit']:4d} "
            f"{r['throughput_sps']:9.1f}  "
            f"{100 * r['energy_compute_frac']:7.1f}  "
            f"{100 * move:8.1f}  "
            f"{100 * r['energy_static_frac']:6.1f}")
    return "\n".join(out)


if __name__ == "__main__":
    print(report(run()))

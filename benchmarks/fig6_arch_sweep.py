"""Fig. 6 reproduction: energy breakdown + throughput across MG sizes
{4, 8, 16} and NoC flit widths {8, 16 B} for a compute-intensive model
(ResNet18) and a compact one (EfficientNetB0), generic mapping.

Trends to validate: ResNet18 throughput scales with MG size with
compute-dominated energy; EfficientNetB0 sees only modest gains while
data movement (NoC + gmem) grows toward the paper's ~55% share at small
MG / wide flit.

Runs on the ``repro.explore`` engine: points fan out over a worker pool
and land in the content-addressed result cache, so re-runs (and any
other sweep touching the same points, e.g. Fig. 7) are free.  The
engine evaluates through the :mod:`repro.flow` pass pipeline —
``flow.compile(...).evaluate(backend=...)`` is the only compile path —
so in-process re-evaluations of a point at a second fidelity reuse the
cached partition pass output.

    PYTHONPATH=src python -m benchmarks.fig6_arch_sweep [--quick]
        [--pool N] [--no-cache]
"""

from __future__ import annotations

import argparse
from typing import Dict, List, Optional

from repro.core.mapping import CostParams
from repro.explore import (ExplorationEngine, default_cache_dir,
                           mg_flit_space)
from repro.explore.space import SWEEP_FLIT, SWEEP_MG

MODELS = ("resnet18", "efficientnetb0")
RES = 112
DEFAULT_POOL = 8


def run(simulate: bool = True, pool: Optional[int] = None,
        cache: bool = True) -> List[Dict]:
    pool = DEFAULT_POOL if pool is None else pool
    space = mg_flit_space(SWEEP_MG, SWEEP_FLIT, strategies=("generic",))
    rows: List[Dict] = []
    for model in MODELS:
        eng = ExplorationEngine(model, res=RES,
                                params=CostParams(batch=4), pool=pool,
                                cache=default_cache_dir() if cache
                                else None)
        recs = eng.sweep(space,
                         fidelity="simulate" if simulate else "analytic")
        rows.extend(r.row() for r in recs)
    return rows


def report(rows: List[Dict]) -> str:
    out = ["model            MG flit  thpt(sps)  compute%  noc+gmem%  "
           "static%"]
    for r in rows:
        move = r["energy_noc_frac"] + r["energy_gmem_frac"] \
            + r["energy_weight_load_frac"]
        out.append(
            f"{r['model']:16s} {r['mg']:2d} {r['flit']:4d} "
            f"{r['throughput_sps']:9.1f}  "
            f"{100 * r['energy_compute_frac']:7.1f}  "
            f"{100 * move:8.1f}  "
            f"{100 * r['energy_static_frac']:6.1f}")
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="analytic cost model instead of the simulator")
    ap.add_argument("--pool", type=int, default=None,
                    help=f"worker processes (default {DEFAULT_POOL})")
    ap.add_argument("--no-cache", action="store_true",
                    help="bypass the on-disk result cache")
    args = ap.parse_args()
    print(report(run(simulate=not args.quick, pool=args.pool,
                     cache=not args.no_cache)))

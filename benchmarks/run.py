"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the full JSON to
``results/bench_*.json``.  ``us_per_call`` is the simulated chip
execution time per sample (cycles @ 1 GHz) for the CIMFlow benchmarks,
and the roofline-bound step time for the dry-run cells.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-roofline]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from benchmarks import fig5_compilation, fig6_arch_sweep, fig7_codesign
from benchmarks import roofline as roofline_mod


def _save(name: str, rows) -> None:
    os.makedirs("results", exist_ok=True)
    with open(f"results/bench_{name}.json", "w") as f:
        json.dump(rows, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="analytic cost model instead of the simulator")
    ap.add_argument("--fidelity", default="trace",
                    choices=("analytic", "trace", "simulate"),
                    help="fig5/fig7 evaluation fidelity (default: "
                         "trace — the calibratable middle rung; "
                         "--quick forces analytic)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)
    simulate = not args.quick
    fidelity = "analytic" if args.quick else args.fidelity

    print("name,us_per_call,derived")

    rows = fig5_compilation.run(fidelity=fidelity)
    _save("fig5", rows)
    for r in rows:
        print(f"fig5.{r['model']}.{r['strategy']},"
              f"{r['cycles'] / 4 / 1e3:.1f},"
              f"speed_norm={r['speed_norm']:.2f};"
              f"energy_norm={r['energy_norm']:.2f}")
    print(fig5_compilation.report(rows), file=sys.stderr)

    rows = fig6_arch_sweep.run(simulate=simulate)
    _save("fig6", rows)
    for r in rows:
        print(f"fig6.{r['model']}.mg{r['mg']}.flit{r['flit']},"
              f"{r['cycles'] / 4 / 1e3:.1f},"
              f"thpt={r['throughput_sps']:.1f};"
              f"compute_frac={r['energy_compute_frac']:.2f}")
    print(fig6_arch_sweep.report(rows), file=sys.stderr)

    rows = fig7_codesign.run(fidelity=fidelity)
    _save("fig7", rows)
    for r in rows:
        print(f"fig7.{r['model']}.{r['strategy']}.mg{r['mg']}."
              f"flit{r['flit']},{r['cycles'] / 4 / 1e3:.1f},"
              f"thpt={r['throughput_sps']:.1f}")
    print(fig7_codesign.report(rows), file=sys.stderr)

    if not args.skip_roofline:
        try:
            rows = roofline_mod.rows()
            _save("roofline", rows)
            for r in rows:
                if r.get("status") != "ok":
                    continue
                bound = max(r["compute_s"], r["memory_s"],
                            r["collective_s"])
                print(f"roofline.{r['arch']}.{r['shape']},"
                      f"{bound * 1e6:.1f},dominant={r['dominant']}")
            print(roofline_mod.report("1pod"), file=sys.stderr)
        except FileNotFoundError:
            print("roofline: results/dryrun.json missing — run "
                  "`python -m repro.launch.dryrun --all` first",
                  file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving benchmark: committed trace replay, engine equivalence,
prefill-policy latency, and the large-trace replay gate.

Four sections (schema 2):

* **policies** — replays the committed 200-request Poisson trace
  (``benchmarks/serving_trace.json``, rate 5000 req/s, seed 0 — tuned
  to ~50% of the default chip's decode capacity so batching policy
  visibly moves the tail) through ``repro.serve`` at trace fidelity
  under both batching policies; throughput and latency percentiles are
  gated exactly against the committed golden.
* **equivalence** — the array-batched engine must produce metrics JSON
  byte-identical to the reference event engine (modulo the
  self-describing ``engine`` key) on the committed trace under both
  policies AND under the degradation config from ``BENCH_faults.json``
  (deadline + shedding + retries).
* **prefill** — chunked and batched prefill vs FIFO batch-1 on an
  over-capacity prompt-heavy workload (synthetic step costs); gates
  the headline invariant that chunked prefill beats FIFO batch-1 on
  p99 TTFT when the prefill engine saturates, plus the exact latency
  numbers.
* **large** — a 120k-request over-capacity trace with long generation
  lengths, pinned by the sha256 of its canonical JSON rather than
  committed (~13 MB) bytes; the trace generators are bit-reproducible
  so the digest IS the trace.  ``--smoke`` measures wall time
  (interleaved min-of-reps) and gates two floors that hold on any
  machine: the array engine must replay the full trace in seconds
  (ceiling ``LARGE_ARRAY_CEIL_S``) and must beat the event engine by
  ``SPEEDUP_FLOOR``x on a 20k-request prefix (a same-machine ratio, so
  no absolute-speed assumption).  Wall-clock numbers are printed and
  written to ``--json`` but never stored in the golden.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
        [--update-golden] [--make-trace] [--skip-large] [--json PATH]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
import warnings
from typing import Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_PATH = os.path.join(_ROOT, "BENCH_serving.json")
TRACE_PATH = os.path.join(_ROOT, "benchmarks", "serving_trace.json")

# committed-trace parameters (only used by --make-trace)
TRACE_RATE = 5000.0
TRACE_REQUESTS = 200
TRACE_SEED = 0

MODEL_KW = dict(n_layers=2, d_model=128, n_heads=4, vocab=256,
                max_prompt=64, max_new=64)
FIDELITY = "trace"
MAX_BATCH = 8

# degradation config mirrored from benchmarks/bench_faults.py — the
# equivalence section must cover the shed/timeout/retry paths too
FAULT_RATE = 300000.0
FAULT_REQUESTS = 200
FAULT_SEED = 1
FAULT_KW = dict(deadline_s=0.002, max_queue=4, max_retries=2,
                retry_backoff_s=0.0005)

# large-trace replay: over-capacity, long generations, pinned by hash
LARGE_REQUESTS = 120_000
LARGE_RATE = 5000.0
LARGE_SEED = 9
LARGE_LEN_KW = dict(min_prompt=4, max_prompt=64, min_new=16,
                    max_new=1024)
SPEEDUP_REQUESTS = 20_000      # event-engine comparison prefix
SPEEDUP_FLOOR = 20.0           # array/event wall-time ratio, same box
LARGE_ARRAY_CEIL_S = 30.0      # full 120k replay must stay in seconds

# prefill-policy section: prompts all land in the top bucket but
# average ~75% of it, so chunked prefill (priced per actual token)
# sustains load that saturates the bucket-padded FIFO path; decode is
# light (short gens) and the batch is wide so chunked prompts are not
# starved of decode slots
PREFILL_RATE = 9000.0
PREFILL_REQUESTS = 3000
PREFILL_SEED = 11
PREFILL_LEN_KW = dict(min_prompt=33, max_prompt=64, min_new=2,
                      max_new=8)
PREFILL_MAX_BATCH = 16
PREFILL_CHUNK_TOKENS = 64

# metric keys gated against the golden (exact match — deterministic)
_GATED = ("tokens", "throughput_tok_s", "throughput_req_s",
          "decode_iterations", "peak_decode_batch", "kv_peak_bytes")
_GATED_PCT = ("ttft_s", "tpot_s", "e2e_s")


def make_trace() -> None:
    from repro.serve import poisson_trace, save_trace
    save_trace(TRACE_PATH, poisson_trace(
        TRACE_RATE, TRACE_REQUESTS, seed=TRACE_SEED,
        max_prompt=MODEL_KW["max_prompt"],
        max_new=MODEL_KW["max_new"]))
    print(f"wrote {TRACE_PATH} ({TRACE_REQUESTS} requests, "
          f"rate {TRACE_RATE} req/s, seed {TRACE_SEED})")


def _synthetic_table(max_new: int):
    """Deterministic step costs without the compiler — the large and
    prefill sections price millions of iterations, where the analytic
    table build (not the replay) would dominate."""
    from repro.serve import ServeModelCfg, StepCostTable
    cfg = ServeModelCfg(max_prompt=64, max_new=max_new)
    pb = [1, 2, 4, 8, 16, 32, 64]
    db, b = [], 1
    while b < cfg.max_seq:
        db.append(b)
        b *= 2
    db.append(cfg.max_seq)
    return StepCostTable.from_costs(
        cfg,
        prefill_s={b: 2e-6 * b for b in pb},
        decode_base_s={b: 30e-6 + 0.01e-6 * b for b in db},
        decode_per_seq_s={b: 2e-6 + 0.002e-6 * b for b in db},
        prefill_base_s={b: 1.5e-6 * b for b in pb},
        prefill_per_seq_s={b: 0.5e-6 * b for b in pb},
    )


def _prefill_table():
    """Prompt-heavy regime: prefill is the expensive stage (2 us per
    bucketed token) while decode steps are light, so the comparison
    isolates the prefill policies — chunked prefill serializes prompt
    chunks with decode iterations, so a decode-bound table would
    measure the decode engine, not the policy."""
    from repro.serve import ServeModelCfg, StepCostTable
    cfg = ServeModelCfg(max_prompt=64,
                        max_new=PREFILL_LEN_KW["max_new"])
    pb = [1, 2, 4, 8, 16, 32, 64]
    db, b = [], 1
    while b < cfg.max_seq:
        db.append(b)
        b *= 2
    db.append(cfg.max_seq)
    return StepCostTable.from_costs(
        cfg,
        prefill_s={b: 2e-6 * b for b in pb},
        decode_base_s={b: 10e-6 for b in db},
        decode_per_seq_s={b: 1e-6 for b in db},
        prefill_base_s={b: 1.5e-6 * b for b in pb},
        prefill_per_seq_s={b: 0.5e-6 * b for b in pb},
    )


def _large_trace():
    from repro.serve import poisson_trace
    return poisson_trace(LARGE_RATE, LARGE_REQUESTS, seed=LARGE_SEED,
                         **LARGE_LEN_KW)


def _trace_sha256(requests) -> str:
    blob = json.dumps(
        [[r.rid, r.t_arrive, r.prompt_len, r.gen_len]
         for r in requests]).encode()
    return hashlib.sha256(blob).hexdigest()


def _run(table, trace, policy="continuous", max_batch=MAX_BATCH,
         **kw) -> Dict:
    from repro.serve import ServeSim, make_policy
    sim = ServeSim(table, make_policy(policy, max_batch), **kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return sim.run(trace)


def _equiv(table, trace, policy="continuous", **kw) -> bool:
    """True iff event and array metrics JSON agree byte-for-byte
    (modulo the self-describing ``engine`` key)."""
    from repro.serve import metrics_json
    out = {}
    for eng in ("event", "array"):
        m = dict(_run(table, trace, policy, engine=eng, **kw))
        m.pop("engine")
        out[eng] = metrics_json(m)
    return out["event"] == out["array"]


def bench_doc() -> Dict:
    from repro.serve import (ServeModelCfg, StepCostTable, load_trace,
                             poisson_trace)
    cfg = ServeModelCfg(**MODEL_KW)
    table = StepCostTable(cfg, fidelity=FIDELITY)
    trace = load_trace(TRACE_PATH)
    policies: Dict[str, Dict] = {}
    for name in ("static", "continuous"):
        policies[name] = _run(table, trace, name)

    fault_trace = poisson_trace(FAULT_RATE, FAULT_REQUESTS,
                                seed=FAULT_SEED)
    equivalence = {
        "static": _equiv(table, trace, "static"),
        "continuous": _equiv(table, trace, "continuous"),
        "degraded": _equiv(table, fault_trace, "continuous",
                           **FAULT_KW),
    }

    ptable = _prefill_table()
    ptrace = poisson_trace(PREFILL_RATE, PREFILL_REQUESTS,
                           seed=PREFILL_SEED, **PREFILL_LEN_KW)
    prefill: Dict[str, Dict] = {}
    for pol in ("fifo", "batched", "chunked"):
        m = _run(ptable, ptrace, prefill_policy=pol,
                 max_batch=PREFILL_MAX_BATCH,
                 chunk_tokens=PREFILL_CHUNK_TOKENS)
        prefill[pol] = {"ttft_s": m["ttft_s"], "tpot_s": m["tpot_s"],
                        "throughput_tok_s": m["throughput_tok_s"],
                        "tokens": m["tokens"]}

    large = _large_trace()
    return {
        "schema": 2,
        "chip": "default",
        "fidelity": FIDELITY,
        "max_batch": MAX_BATCH,
        "model": cfg.to_dict(),
        "trace": {"path": "benchmarks/serving_trace.json",
                  "rate": TRACE_RATE, "requests": TRACE_REQUESTS,
                  "seed": TRACE_SEED},
        "policies": policies,
        "equivalence": equivalence,
        "prefill": {
            "rate": PREFILL_RATE, "requests": PREFILL_REQUESTS,
            "seed": PREFILL_SEED, **PREFILL_LEN_KW,
            "max_batch": PREFILL_MAX_BATCH,
            "chunk_tokens": PREFILL_CHUNK_TOKENS,
            "policies": prefill,
        },
        "large": {
            "requests": LARGE_REQUESTS, "rate": LARGE_RATE,
            "seed": LARGE_SEED, **LARGE_LEN_KW,
            "trace_sha256": _trace_sha256(large),
            "decode_iterations":
                _run(_synthetic_table(LARGE_LEN_KW["max_new"]),
                     large)["decode_iterations"],
        },
    }


def measure_large(doc: Dict) -> Dict:
    """Wall-clock section (never golden-gated): interleaved min-of-reps
    for the array/event ratio on the speedup prefix, plus the full
    large-trace array replay time."""
    table = _synthetic_table(LARGE_LEN_KW["max_new"])
    large = _large_trace()
    if _trace_sha256(large) != doc["large"]["trace_sha256"]:
        raise RuntimeError("large trace drifted from pinned sha256")
    prefix = large[:SPEEDUP_REQUESTS]

    def clock(engine, trace) -> float:
        t0 = time.perf_counter()
        _run(table, trace, engine=engine)
        return time.perf_counter() - t0

    # interleave so machine noise hits both engines alike; keep mins
    ar, ev = [], []
    for _ in range(2):
        ar.append(clock("array", prefix))
        ev.append(clock("event", prefix))
    ar.append(clock("array", prefix))
    full = min(clock("array", large) for _ in range(2))
    return {
        "speedup_requests": SPEEDUP_REQUESTS,
        "array_s": min(ar),
        "event_s": min(ev),
        "speedup": min(ev) / min(ar),
        "full_requests": LARGE_REQUESTS,
        "full_array_s": full,
    }


def report(doc: Dict) -> str:
    out = [f"== serving bench (default chip, fidelity={FIDELITY}, "
           f"max_batch={MAX_BATCH}) =="]
    for name, m in doc["policies"].items():
        out.append(
            f"{name:<11s} tok/s={m['throughput_tok_s']:9.0f}  "
            f"ttft p99={m['ttft_s']['p99'] * 1e3:7.3f}ms  "
            f"tpot p99={m['tpot_s']['p99'] * 1e6:7.1f}us  "
            f"e2e p99={m['e2e_s']['p99'] * 1e3:7.3f}ms")
    eq = doc["equivalence"]
    out.append("engine equivalence (array vs event, byte-exact): "
               + ", ".join(f"{k}={'OK' if v else 'FAIL'}"
                           for k, v in sorted(eq.items())))
    out.append("prefill policies @ over-capacity "
               f"(rate {doc['prefill']['rate']:g}/s):")
    for pol, m in doc["prefill"]["policies"].items():
        out.append(
            f"  {pol:<8s} ttft p50={m['ttft_s']['p50'] * 1e3:8.3f}ms "
            f"p99={m['ttft_s']['p99'] * 1e3:8.3f}ms  "
            f"tok/s={m['throughput_tok_s']:9.0f}")
    lg = doc["large"]
    out.append(f"large trace: {lg['requests']} requests, "
               f"{lg['decode_iterations']} decode iterations, "
               f"sha256={lg['trace_sha256'][:12]}…")
    return "\n".join(out)


def _round(x: float) -> float:
    return round(float(x), 9)


def smoke_drift(doc: Dict, golden: Dict) -> List[str]:
    """Failures vs the committed golden (empty = clean)."""
    drift: List[str] = []
    for name in sorted(set(doc["policies"]) | set(golden["policies"])):
        m = doc["policies"].get(name)
        g = golden["policies"].get(name)
        if m is None or g is None:
            drift.append(f"{name}: {'missing' if m is None else 'new'} "
                         f"vs golden")
            continue
        for k in _GATED:
            if _round(m[k]) != _round(g[k]):
                drift.append(f"{name}.{k}: {g[k]} -> {m[k]}")
        for fam in _GATED_PCT:
            for q in ("p50", "p95", "p99", "mean"):
                if _round(m[fam][q]) != _round(g[fam][q]):
                    drift.append(
                        f"{name}.{fam}.{q}: {g[fam][q]} -> {m[fam][q]}")
    # engine equivalence is not a drift check — it must simply hold
    for k, ok in sorted(doc["equivalence"].items()):
        if not ok:
            drift.append(f"equivalence.{k}: array engine diverged "
                         f"from the event engine")
    # prefill latency numbers are deterministic: gate them exactly
    for pol in sorted(set(doc["prefill"]["policies"])
                      | set(golden["prefill"]["policies"])):
        m = doc["prefill"]["policies"].get(pol)
        g = golden["prefill"]["policies"].get(pol)
        if m is None or g is None:
            drift.append(f"prefill.{pol}: "
                         f"{'missing' if m is None else 'new'}")
            continue
        for q in ("p50", "p99"):
            if _round(m["ttft_s"][q]) != _round(g["ttft_s"][q]):
                drift.append(f"prefill.{pol}.ttft.{q}: "
                             f"{g['ttft_s'][q]} -> {m['ttft_s'][q]}")
    # the headline prefill invariant, independent of the golden
    pf = doc["prefill"]["policies"]
    if pf["chunked"]["ttft_s"]["p99"] >= pf["fifo"]["ttft_s"]["p99"]:
        drift.append(
            f"chunked prefill p99 ttft {pf['chunked']['ttft_s']['p99']}"
            f" no longer beats fifo {pf['fifo']['ttft_s']['p99']}")
    if doc["large"]["trace_sha256"] != golden["large"]["trace_sha256"]:
        drift.append("large.trace_sha256: pinned trace drifted "
                     f"({golden['large']['trace_sha256'][:12]}… -> "
                     f"{doc['large']['trace_sha256'][:12]}…)")
    if doc["large"]["decode_iterations"] != \
            golden["large"]["decode_iterations"]:
        drift.append(
            f"large.decode_iterations: "
            f"{golden['large']['decode_iterations']} -> "
            f"{doc['large']['decode_iterations']}")
    # the serving invariant itself, independent of the golden
    ms, mc = doc["policies"]["static"], doc["policies"]["continuous"]
    if mc["throughput_tok_s"] < 0.95 * ms["throughput_tok_s"]:
        drift.append("continuous throughput fell below static's")
    if mc["tpot_s"]["p99"] >= ms["tpot_s"]["p99"]:
        drift.append(
            f"continuous p99 tpot {mc['tpot_s']['p99']} no longer "
            f"beats static {ms['tpot_s']['p99']}")
    return drift


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="gate against the committed golden (CI job)")
    ap.add_argument("--update-golden", action="store_true",
                    help=f"rewrite {GOLDEN_PATH}")
    ap.add_argument("--make-trace", action="store_true",
                    help=f"regenerate {TRACE_PATH}")
    ap.add_argument("--skip-large", action="store_true",
                    help="skip the wall-clock large-trace section")
    ap.add_argument("--json", default="results/bench_serving.json",
                    help="also write the measured doc here "
                         "('' to skip)")
    args = ap.parse_args(argv)

    if args.make_trace:
        make_trace()
        if not (args.smoke or args.update_golden):
            return 0
    if not os.path.exists(TRACE_PATH):
        print(f"trace {TRACE_PATH} missing "
              f"(generate with --make-trace)")
        return 1

    doc = bench_doc()
    print(report(doc))
    timing = None
    if not args.skip_large:
        timing = measure_large(doc)
        print(f"large-trace replay: array {timing['array_s']:.2f}s vs "
              f"event {timing['event_s']:.2f}s on "
              f"{timing['speedup_requests']} requests -> "
              f"{timing['speedup']:.1f}x; full "
              f"{timing['full_requests']}-request trace in "
              f"{timing['full_array_s']:.2f}s (array)")
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(dict(doc, timing=timing), f, indent=1,
                      sort_keys=True)
        print(f"wrote {args.json}")
    if args.update_golden:
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"golden updated: {GOLDEN_PATH}")
        return 0
    if args.smoke:
        try:
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        except FileNotFoundError:
            print(f"golden {GOLDEN_PATH} missing "
                  f"(generate with --update-golden)")
            return 1
        drift = smoke_drift(doc, golden)
        if timing is not None:
            if timing["speedup"] < SPEEDUP_FLOOR:
                drift.append(
                    f"array engine speedup {timing['speedup']:.1f}x "
                    f"fell below the {SPEEDUP_FLOOR:.0f}x floor "
                    f"(array {timing['array_s']:.2f}s, event "
                    f"{timing['event_s']:.2f}s)")
            if timing["full_array_s"] > LARGE_ARRAY_CEIL_S:
                drift.append(
                    f"full {LARGE_REQUESTS}-request replay took "
                    f"{timing['full_array_s']:.1f}s "
                    f"(> {LARGE_ARRAY_CEIL_S:.0f}s ceiling)")
        if drift:
            print("SERVING BENCH DRIFT vs committed golden:")
            for d in drift:
                print(f"  {d}")
            print("if the cost-model change is intentional, regenerate "
                  "with `python -m benchmarks.bench_serve "
                  "--update-golden` and commit the diff")
            return 1
        gc = golden["policies"]["continuous"]
        print("golden: clean (committed continuous "
              f"tok/s={gc['throughput_tok_s']:.0f}, "
              f"p99 tpot={gc['tpot_s']['p99'] * 1e6:.1f}us)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

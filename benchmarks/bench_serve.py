"""Serving benchmark: committed trace replay + latency golden.

Replays the committed 200-request Poisson trace
(``benchmarks/serving_trace.json``, rate 5000 req/s, seed 0 — tuned to
~50% of the default chip's decode capacity so batching policy visibly
moves the tail) through ``repro.serve`` at trace fidelity under both
batching policies, and records throughput plus the latency percentiles.

The committed golden is ``BENCH_serving.json`` at the repo root.  The
simulator touches no wall clock — every recorded number derives from
deterministic cycle counts — so ``--smoke`` fails on ANY drift of
throughput or percentiles (cost-model/codegen change: regenerate with
``--update-golden`` and commit the diff).  ``--smoke`` additionally
asserts the serving invariant the ISSUE pins: continuous batching
beats static on p99 per-token latency at equal delivered throughput.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
        [--update-golden] [--make-trace] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_PATH = os.path.join(_ROOT, "BENCH_serving.json")
TRACE_PATH = os.path.join(_ROOT, "benchmarks", "serving_trace.json")

# committed-trace parameters (only used by --make-trace)
TRACE_RATE = 5000.0
TRACE_REQUESTS = 200
TRACE_SEED = 0

MODEL_KW = dict(n_layers=2, d_model=128, n_heads=4, vocab=256,
                max_prompt=64, max_new=64)
FIDELITY = "trace"
MAX_BATCH = 8

# metric keys gated against the golden (exact match — deterministic)
_GATED = ("tokens", "throughput_tok_s", "throughput_req_s",
          "decode_iterations", "peak_decode_batch", "kv_peak_bytes")
_GATED_PCT = ("ttft_s", "tpot_s", "e2e_s")


def make_trace() -> None:
    from repro.serve import poisson_trace, save_trace
    save_trace(TRACE_PATH, poisson_trace(
        TRACE_RATE, TRACE_REQUESTS, seed=TRACE_SEED,
        max_prompt=MODEL_KW["max_prompt"],
        max_new=MODEL_KW["max_new"]))
    print(f"wrote {TRACE_PATH} ({TRACE_REQUESTS} requests, "
          f"rate {TRACE_RATE} req/s, seed {TRACE_SEED})")


def bench_doc() -> Dict:
    from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                             load_trace, make_policy)
    cfg = ServeModelCfg(**MODEL_KW)
    table = StepCostTable(cfg, fidelity=FIDELITY)
    trace = load_trace(TRACE_PATH)
    policies: Dict[str, Dict] = {}
    for name in ("static", "continuous"):
        sim = ServeSim(table, make_policy(name, MAX_BATCH))
        policies[name] = sim.run(trace)
    return {
        "schema": 1,
        "chip": "default",
        "fidelity": FIDELITY,
        "max_batch": MAX_BATCH,
        "model": cfg.to_dict(),
        "trace": {"path": "benchmarks/serving_trace.json",
                  "rate": TRACE_RATE, "requests": TRACE_REQUESTS,
                  "seed": TRACE_SEED},
        "policies": policies,
    }


def report(doc: Dict) -> str:
    out = [f"== serving bench (default chip, fidelity={FIDELITY}, "
           f"max_batch={MAX_BATCH}) =="]
    for name, m in doc["policies"].items():
        out.append(
            f"{name:<11s} tok/s={m['throughput_tok_s']:9.0f}  "
            f"ttft p99={m['ttft_s']['p99'] * 1e3:7.3f}ms  "
            f"tpot p99={m['tpot_s']['p99'] * 1e6:7.1f}us  "
            f"e2e p99={m['e2e_s']['p99'] * 1e3:7.3f}ms")
    return "\n".join(out)


def _round(x: float) -> float:
    return round(float(x), 9)


def smoke_drift(doc: Dict, golden: Dict) -> List[str]:
    """Failures vs the committed golden (empty = clean)."""
    drift: List[str] = []
    for name in sorted(set(doc["policies"]) | set(golden["policies"])):
        m = doc["policies"].get(name)
        g = golden["policies"].get(name)
        if m is None or g is None:
            drift.append(f"{name}: {'missing' if m is None else 'new'} "
                         f"vs golden")
            continue
        for k in _GATED:
            if _round(m[k]) != _round(g[k]):
                drift.append(f"{name}.{k}: {g[k]} -> {m[k]}")
        for fam in _GATED_PCT:
            for q in ("p50", "p95", "p99", "mean"):
                if _round(m[fam][q]) != _round(g[fam][q]):
                    drift.append(
                        f"{name}.{fam}.{q}: {g[fam][q]} -> {m[fam][q]}")
    # the serving invariant itself, independent of the golden
    ms, mc = doc["policies"]["static"], doc["policies"]["continuous"]
    if mc["throughput_tok_s"] < 0.95 * ms["throughput_tok_s"]:
        drift.append("continuous throughput fell below static's")
    if mc["tpot_s"]["p99"] >= ms["tpot_s"]["p99"]:
        drift.append(
            f"continuous p99 tpot {mc['tpot_s']['p99']} no longer "
            f"beats static {ms['tpot_s']['p99']}")
    return drift


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="gate against the committed golden (CI job)")
    ap.add_argument("--update-golden", action="store_true",
                    help=f"rewrite {GOLDEN_PATH}")
    ap.add_argument("--make-trace", action="store_true",
                    help=f"regenerate {TRACE_PATH}")
    ap.add_argument("--json", default="results/bench_serving.json",
                    help="also write the measured doc here "
                         "('' to skip)")
    args = ap.parse_args(argv)

    if args.make_trace:
        make_trace()
        if not (args.smoke or args.update_golden):
            return 0
    if not os.path.exists(TRACE_PATH):
        print(f"trace {TRACE_PATH} missing "
              f"(generate with --make-trace)")
        return 1

    doc = bench_doc()
    print(report(doc))
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.update_golden:
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"golden updated: {GOLDEN_PATH}")
        return 0
    if args.smoke:
        try:
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        except FileNotFoundError:
            print(f"golden {GOLDEN_PATH} missing "
                  f"(generate with --update-golden)")
            return 1
        drift = smoke_drift(doc, golden)
        if drift:
            print("SERVING BENCH DRIFT vs committed golden:")
            for d in drift:
                print(f"  {d}")
            print("if the cost-model change is intentional, regenerate "
                  "with `python -m benchmarks.bench_serve "
                  "--update-golden` and commit the diff")
            return 1
        gc = golden["policies"]["continuous"]
        print("golden: clean (committed continuous "
              f"tok/s={gc['throughput_tok_s']:.0f}, "
              f"p99 tpot={gc['tpot_s']['p99'] * 1e6:.1f}us)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

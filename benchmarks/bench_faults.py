"""Fault-injection benchmark: degradation, failover and shedding golden.

Pins the three robustness surfaces of ``repro.faults`` to committed
numbers (``BENCH_faults.json`` at the repo root):

* **fault-free identity** — a ``FaultModel(rate=0)`` fault set leaves
  the numpy oracle bit-unchanged (asserted inline) and the clean
  outputs hash to a recorded checksum, so any silent change to the
  fault-free path fails the smoke;
* **fixed-seed degradation** — the tiny_cnn stuck-at degradation curve
  (BER / top-1 agreement per fault rate) plus the closed-form residual
  rates and machine-model overheads of each protection level;
* **mesh failover** — analytic throughput of a 2x2 pipeline mesh
  healthy vs with one failed chip (the re-planned, degraded mode);
* **serving degradation** — a deliberately over-capacity Poisson
  burst through ``repro.serve`` with deadlines + load shedding:
  nonzero shed/timeout/retry counters and goodput < throughput.

Everything derives from seeded draws and deterministic cost models —
no wall clock — so ``--smoke`` fails on ANY numeric drift (regenerate
with ``--update-golden`` and commit the diff when intentional).

    PYTHONPATH=src python -m benchmarks.bench_faults [--smoke]
        [--update-golden] [--json PATH]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import warnings
from typing import Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_PATH = os.path.join(_ROOT, "BENCH_faults.json")

WORKLOAD_KW = dict(res=8, c=8)
BATCH = 2
SEED = 0
RATES = (0.0, 1e-3, 5e-3, 2e-2)
RAW_RATE = 1e-3                      # rate the mitigation table assumes

SERVE_RATE = 300000.0                # ~3x the analytic prefill capacity
SERVE_REQUESTS = 200
SERVE_SEED = 1
SERVE_KW = dict(deadline_s=0.002, max_queue=4, max_retries=2,
                retry_backoff_s=0.0005)

_MESH_KEYS = ("cycles", "throughput_sps", "n_chips", "n_failed_chips")
_SERVE_KEYS = ("requests", "tokens", "shed_requests",
               "timeout_requests", "retries", "goodput_tok_s",
               "throughput_tok_s")


def _clean_identity() -> Dict:
    """Assert rate-0 leaves the oracle bit-unchanged; hash the outputs."""
    import numpy as np

    from repro.core import ref, workloads
    from repro.core.arch import default_chip
    from repro.faults import FaultModel, resolve_faults

    cg = workloads.build("tiny_cnn", **WORKLOAD_KW).condense()
    weights, biases, inputs = ref.random_init(cg, batch=BATCH, seed=SEED)
    quant = ref.auto_quant(cg, weights, biases, inputs)
    clean = ref.run_reference(cg, weights, biases, quant, inputs)
    fs = resolve_faults(weights, default_chip(), FaultModel(rate=0.0))
    gated = ref.run_reference(cg, weights, biases, quant, inputs,
                              faults=fs)
    for gid in clean:
        if not np.array_equal(clean[gid], gated[gid]):
            raise AssertionError(
                f"FaultModel(rate=0) changed group {gid} — the "
                f"fault-free path is no longer an exact no-op")
    h = hashlib.sha256()
    for gid in sorted(clean):
        h.update(np.ascontiguousarray(clean[gid]).tobytes())
    return {"n_stuck": fs.n_stuck, "output_sha256": h.hexdigest()}


def _degradation() -> List[Dict]:
    from repro.core import workloads
    from repro.core.arch import default_chip
    from repro.faults import degradation_curve

    cg = workloads.build("tiny_cnn", **WORKLOAD_KW).condense()
    return degradation_curve(cg, default_chip(), RATES, batch=BATCH,
                             seed=SEED)


def _mitigation() -> Dict[str, Dict]:
    from repro.core.arch import ProtectionConfig, default_chip
    from repro.core.machine import machine_for
    from repro.faults import residual_rate

    out: Dict[str, Dict] = {}
    for name, prot in (
            ("none", ProtectionConfig()),
            ("ecc", ProtectionConfig(ecc=True)),
            ("spare4", ProtectionConfig(spare_rows=4)),
            ("tmr", ProtectionConfig(tmr=True)),
            ("full", ProtectionConfig(ecc=True, spare_rows=4,
                                      tmr=True))):
        chip = default_chip(protection=prot)
        m = machine_for(chip)
        out[name] = {
            "residual_rate": residual_rate(RAW_RATE, prot,
                                           chip.core.cim.macro),
            "weight_load_factor": m.weight_load_factor,
            "area_factor": m.protection_area_factor,
            "mvm_fill_beats": m.mvm_fill_beats,
        }
    return out


def _mesh_failover() -> Dict[str, Dict]:
    from repro import flow
    from repro.core.arch import default_chip
    from repro.flow import CompileOptions
    from repro.system import SystemConfig

    out: Dict[str, Dict] = {}
    for name, sysc in (
            ("healthy", SystemConfig.mesh(4)),
            # chip 1 is on the healthy plan's route (the plan uses 3
            # of the 4 chips), so its loss forces a genuine re-plan
            ("one_chip_down",
             SystemConfig.mesh(4).degrade(failed_chips=(1,)))):
        rep = flow.compile("tiny_cnn", default_chip(), CompileOptions(
            fidelity="analytic", batch=BATCH, workload_kw=WORKLOAD_KW,
            system=sysc)).evaluate()
        out[name] = {"cycles": rep.cycles,
                     "throughput_sps": rep.throughput_sps,
                     "n_chips": rep.n_chips,
                     "n_failed_chips": rep.n_failed_chips}
    return out


def _serving_overload() -> Dict:
    from repro.serve import (ServeModelCfg, ServeSim, StepCostTable,
                             make_policy, poisson_trace)

    table = StepCostTable(ServeModelCfg(), fidelity="analytic")
    trace = poisson_trace(SERVE_RATE, SERVE_REQUESTS, seed=SERVE_SEED)
    sim = ServeSim(table, make_policy("continuous", 8), **SERVE_KW)
    with warnings.catch_warnings():
        # the overload is the point; the saturation warning is for
        # interactive users, not the golden
        warnings.simplefilter("ignore", RuntimeWarning)
        m = sim.run(trace)
    return {k: m[k] for k in _SERVE_KEYS}


def bench_doc() -> Dict:
    return {
        "schema": 1,
        "chip": "default",
        "workload": {"model": "tiny_cnn", **WORKLOAD_KW,
                     "batch": BATCH, "seed": SEED},
        "clean_identity": _clean_identity(),
        "degradation": _degradation(),
        "mitigation": {"raw_rate": RAW_RATE, "levels": _mitigation()},
        "mesh_failover": _mesh_failover(),
        "serving_overload": {
            "rate": SERVE_RATE, "requests": SERVE_REQUESTS,
            "seed": SERVE_SEED, **SERVE_KW,
            "metrics": _serving_overload()},
    }


def report(doc: Dict) -> str:
    out = ["== fault-injection bench (tiny_cnn, default chip) =="]
    out.append("rate        n_stuck   BER          top-1 agree")
    for row in doc["degradation"]:
        out.append(f"{row['rate']:<10.4g}  {row['n_stuck']:<8.0f}  "
                   f"{row['ber']:<11.4g}  {row['top1_agreement']:.3f}")
    mf = doc["mesh_failover"]
    out.append(
        f"mesh 2x2: healthy {mf['healthy']['throughput_sps']:.1f} sps"
        f" -> 1 chip down "
        f"{mf['one_chip_down']['throughput_sps']:.1f} sps")
    sv = doc["serving_overload"]["metrics"]
    out.append(
        f"serving overload: shed={sv['shed_requests']} "
        f"timeout={sv['timeout_requests']} retries={sv['retries']} "
        f"goodput={sv['goodput_tok_s']:.0f}/"
        f"{sv['throughput_tok_s']:.0f} tok/s")
    return "\n".join(out)


def _round(x) -> float:
    return round(float(x), 9)


def smoke_drift(doc: Dict, golden: Dict) -> List[str]:
    """Failures vs the committed golden (empty = clean)."""
    drift: List[str] = []
    if doc["clean_identity"] != golden["clean_identity"]:
        drift.append(f"clean_identity: {golden['clean_identity']} -> "
                     f"{doc['clean_identity']}")
    rows, grows = doc["degradation"], golden["degradation"]
    if len(rows) != len(grows):
        drift.append(f"degradation rows: {len(grows)} -> {len(rows)}")
    for row, grow in zip(rows, grows):
        for k in ("rate", "n_stuck", "ber", "top1_agreement"):
            if _round(row[k]) != _round(grow[k]):
                drift.append(f"degradation[{row['rate']}].{k}: "
                             f"{grow[k]} -> {row[k]}")
    for name, g in golden["mitigation"]["levels"].items():
        m = doc["mitigation"]["levels"].get(name)
        if m is None:
            drift.append(f"mitigation.{name}: missing")
            continue
        for k in g:
            if _round(m[k]) != _round(g[k]):
                drift.append(f"mitigation.{name}.{k}: {g[k]} -> {m[k]}")
    for name in ("healthy", "one_chip_down"):
        m, g = doc["mesh_failover"][name], golden["mesh_failover"][name]
        for k in _MESH_KEYS:
            if _round(m[k]) != _round(g[k]):
                drift.append(f"mesh.{name}.{k}: {g[k]} -> {m[k]}")
    sv = doc["serving_overload"]["metrics"]
    gv = golden["serving_overload"]["metrics"]
    for k in _SERVE_KEYS:
        if _round(sv[k]) != _round(gv[k]):
            drift.append(f"serving.{k}: {gv[k]} -> {sv[k]}")

    # invariants, independent of the golden
    d0 = doc["degradation"][0]
    if d0["rate"] != 0.0 or d0["ber"] != 0.0 \
            or d0["top1_agreement"] != 1.0 or d0["n_stuck"] != 0:
        drift.append(f"rate-0 row is not a clean no-op: {d0}")
    stuck = [r["n_stuck"] for r in doc["degradation"]]
    if stuck != sorted(stuck):
        drift.append(f"n_stuck not monotone in rate: {stuck}")
    hm = doc["mesh_failover"]
    if hm["one_chip_down"]["throughput_sps"] >= \
            hm["healthy"]["throughput_sps"]:
        drift.append("degraded mesh throughput did not drop")
    if sv["shed_requests"] <= 0 or sv["timeout_requests"] <= 0:
        drift.append(f"overload scenario shed nothing: {sv}")
    if sv["goodput_tok_s"] >= sv["throughput_tok_s"]:
        drift.append("goodput did not fall below throughput under "
                     "overload")
    return drift


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="gate against the committed golden (CI job)")
    ap.add_argument("--update-golden", action="store_true",
                    help=f"rewrite {GOLDEN_PATH}")
    ap.add_argument("--json", default="results/bench_faults.json",
                    help="also write the measured doc here "
                         "('' to skip)")
    args = ap.parse_args(argv)

    doc = bench_doc()
    print(report(doc))
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.update_golden:
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"golden updated: {GOLDEN_PATH}")
        return 0
    if args.smoke:
        try:
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        except FileNotFoundError:
            print(f"golden {GOLDEN_PATH} missing "
                  f"(generate with --update-golden)")
            return 1
        drift = smoke_drift(doc, golden)
        if drift:
            print("FAULT BENCH DRIFT vs committed golden:")
            for d in drift:
                print(f"  {d}")
            print("if the fault-model change is intentional, regenerate "
                  "with `python -m benchmarks.bench_faults "
                  "--update-golden` and commit the diff")
            return 1
        g = golden["degradation"][-1]
        print(f"golden: clean (rate {g['rate']:g} -> BER "
              f"{g['ber']:.4g}, top-1 {g['top1_agreement']:.3f}; "
              f"degraded-mesh and shedding numbers pinned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

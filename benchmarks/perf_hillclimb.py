import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512"
                           ).strip()

"""§Perf hillclimb driver.

Two modes:

* ``--mode arch`` (default) — hill-climb the CIM architecture space with
  the ``repro.explore`` engine: restarted stochastic hill-climbing over
  the full 5-dimension design space (MG size, MG count, core grid, flit
  width, local-mem size, strategy), minimizing energy-delay product with
  the analytic cost model, then validating the winner on the
  cycle-accurate simulator.  Evaluations run through the
  :mod:`repro.flow` pipeline, so the final simulator validation of the
  winning point reuses its cached partition.  Every evaluation is
  appended to ``results/arch_hillclimb.jsonl`` and shared through the
  explore cache.

* ``--mode ladder`` — the original roofline hypothesis ladders: chosen
  (arch x shape) cells through the dry-run probes with tuning knobs
  flipped one hypothesis at a time, appending records to
  ``results/perf_log.json`` (rendered into EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--mode arch]
        [--model M] [--iters N] [--pool N]
    PYTHONPATH=src python -m benchmarks.perf_hillclimb --mode ladder
        [--cell N] [--steps N]
"""

import argparse
import json
import time
from typing import Dict, List

# The three ladder cells (chosen from the baseline table):
#  1. most collective-bound    2. worst capacity/memory (paper-technique:
#  the planner's capacity wall)   3. bandwidth-bound decode (the paper's
#  INT8 CIM inference story).
CELLS = [
    ("deepseek-coder-33b", "train_4k"),
    ("deepseek-v3-671b", "train_4k"),
    ("deepseek-coder-33b", "decode_32k"),
]

# Per-cell hypothesis ladders: (knobs, hypothesis text)
LADDERS: Dict[int, List] = {
    0: [
        (dict(attn_seq_parallel=True),
         "head_dim-fallback attention psums every (S,S) score tile "
         "(~60 GB/layer f32): resharding q seq-wise over 'model' and "
         "computing full-head attention per sequence slice replaces the "
         "S^2 psum with S-linear all-to-alls -> collective term should "
         "drop >10x; compute/memory unchanged"),
        (dict(attn_seq_parallel=True, remat_policy="dots"),
         "useful-flops ratio 0.71 == full-remat recompute; saving matmul "
         "outputs (dots_saveable) removes the recomputed fwd -> compute "
         "term ~ -25%, memory/chip rises by saved activations"),
        (dict(attn_seq_parallel=True, fsdp_params=True),
         "33B x fp32 Adam state = 198 GiB/chip replicated over data; "
         "ZeRO-3 sharding over the 16-way data axis should cut "
         "params+state ~16x for ~1 extra param all-gather per layer"),
    ],
    1: [
        (dict(fsdp_params=True),
         "671B cannot fit: bf16 params alone are 84 GiB/chip when "
         "sharded only over 'model'; FSDP over data(16) divides weights "
         "+ moments by 16 -> ~63 GiB/chip closer to feasible; collective "
         "term rises by per-layer weight all-gathers"),
        (dict(fsdp_params=True, remat_policy="dots"),
         "with capacity recovered, buy back the remat recompute: "
         "compute term -25% for a bounded activation-memory increase"),
    ],
    2: [
        (dict(int8_kv_cache=True),
         "decode at 32k is KV-bandwidth-bound: INT8 cache halves the "
         "dominant read stream -> memory term ~ -35-45% (cache is most "
         "but not all of 'bytes accessed')"),
        (dict(int8_kv_cache=True, int8_weights=True),
         "remaining decode bytes are weight reads (4.1 GiB/chip/step "
         "bf16): INT8 weights (the paper's digital-CIM INT8 inference "
         "applied at pod scale) halve them too"),
    ],
}

OUT = "results/perf_log.json"
ARCH_OUT = "results/arch_hillclimb.jsonl"


# ---------------------------------------------------------------------------
# arch mode: hill-climb the CIM design space on the explore engine
# ---------------------------------------------------------------------------


def run_arch(model: str, iters: int, pool: int, seed: int) -> int:
    from repro.core.mapping import CostParams
    from repro.explore import (ExplorationEngine, by_edp,
                               default_cache_dir, default_space,
                               hill_climb)

    eng = ExplorationEngine(model, res=112, params=CostParams(batch=4),
                            pool=pool, cache=default_cache_dir(),
                            store=ARCH_OUT)
    space = default_space()
    print(f"[arch] hill-climbing {space.describe()}\n"
          f"[arch] model={model} objective=EDP iters={iters} "
          f"pool={pool}", flush=True)
    t0 = time.time()
    res = hill_climb(eng, space, objective=by_edp, seed=seed,
                     iters=iters, neighbors=4, restarts=3)
    p = res.best.point
    print(f"[arch] {res.n_evals} evaluations in "
          f"{time.time() - t0:.1f}s (cache {eng.cache_stats()})")
    print(f"[arch] best: {p.strategy} MG={p.macros_per_group} "
          f"n_mg={p.n_macro_groups} cores={p.n_cores} "
          f"flit={p.flit_bytes} lmem={p.local_mem_kb}KB -> "
          f"EDP {res.best.edp:.4g} ({res.best.cycles:.0f} cyc, "
          f"{res.best.energy_total / 1e6:.2f} mJ)")
    sim = eng.evaluate_one(p, fidelity="simulate")
    print(f"[arch] simulator validation: {sim.cycles:.0f} cycles, "
          f"{sim.energy_total / 1e6:.2f} mJ, "
          f"{sim.throughput_sps:.1f} sps")
    print(f"[arch] trace appended to {ARCH_OUT}")
    return 0


# ---------------------------------------------------------------------------
# ladder mode: roofline hypothesis ladders (original driver)
# ---------------------------------------------------------------------------


def run_probe(arch: str, shape: str) -> Dict:
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, multi_pod=False)
    assert rec["status"] == "ok", rec.get("error")
    keep = {"roofline": rec["roofline"],
            "memory": rec.get("memory"),
            "useful_flops_frac": rec.get("useful_flops_frac"),
            "head_sharding": rec.get("head_sharding")}
    return keep


def run_ladder(cell, steps) -> int:
    from repro.launch import tuning

    try:
        with open(OUT) as f:
            log = json.load(f)
    except (OSError, json.JSONDecodeError):
        log = []

    cells = [cell] if cell is not None else list(range(len(CELLS)))
    for ci in cells:
        arch, shape = CELLS[ci]
        key_base = f"{arch}|{shape}"
        done = {e["config"] for e in log if e["cell"] == key_base}
        if "baseline" not in done:
            print(f"[baseline] {key_base}", flush=True)
            t0 = time.time()
            base = run_probe(arch, shape)
            log.append({"cell": key_base, "config": "baseline",
                        "knobs": {}, "hypothesis": "paper-faithful "
                        "baseline (divisibility-fallback sharding, full "
                        "remat, bf16 caches/weights)",
                        "result": base,
                        "wall_s": round(time.time() - t0, 1)})
            _save(log)
        ladder = LADDERS[ci][:steps] if steps else LADDERS[ci]
        for si, (knobs, hypothesis) in enumerate(ladder):
            name = "+".join(sorted(k for k, v in knobs.items()
                                   if v not in (False, "nothing")))
            if name in done:
                continue
            print(f"[{key_base}] step {si}: {name}", flush=True)
            t0 = time.time()
            try:
                with tuning.tuned(**knobs):
                    res = run_probe(arch, shape)
                entry = {"cell": key_base, "config": name,
                         "knobs": knobs, "hypothesis": hypothesis,
                         "result": res,
                         "wall_s": round(time.time() - t0, 1)}
            except Exception as e:       # noqa: BLE001
                entry = {"cell": key_base, "config": name,
                         "knobs": knobs, "hypothesis": hypothesis,
                         "error": f"{type(e).__name__}: {e}",
                         "wall_s": round(time.time() - t0, 1)}
            log.append(entry)
            _save(log)
            r = entry.get("result", {}).get("roofline")
            if r:
                print(f"  -> compute {r['compute_s']:.3g}s "
                      f"mem {r['memory_s']:.3g}s "
                      f"coll {r['collective_s']:.3g}s "
                      f"dom {r['dominant']}", flush=True)
            else:
                print(f"  -> ERROR {entry.get('error')}", flush=True)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("arch", "ladder"), default=None,
                    help="arch: hill-climb the CIM design space "
                         "(repro.explore); ladder: roofline hypothesis "
                         "ladders. Defaults to arch, or to ladder when "
                         "a ladder-only flag (--cell/--steps) is given")
    ap.add_argument("--model", default="resnet18",
                    help="[arch] workload to optimize the chip for")
    ap.add_argument("--iters", type=int, default=24,
                    help="[arch] hill-climb step budget")
    ap.add_argument("--pool", type=int, default=4,
                    help="[arch] worker processes")
    ap.add_argument("--seed", type=int, default=0,
                    help="[arch] search seed")
    ap.add_argument("--cell", type=int, default=None,
                    help="[ladder] run only this cell index (0..2)")
    ap.add_argument("--steps", type=int, default=None,
                    help="[ladder] run only the first N ladder steps")
    args = ap.parse_args()
    ladder_flags = args.cell is not None or args.steps is not None
    if args.mode is None:
        args.mode = "ladder" if ladder_flags else "arch"
    if args.mode == "arch":
        if ladder_flags:
            ap.error("--cell/--steps apply to --mode ladder only")
        return run_arch(args.model, args.iters, args.pool, args.seed)
    return run_ladder(args.cell, args.steps)


def _save(log) -> None:
    os.makedirs("results", exist_ok=True)
    with open(OUT + ".tmp", "w") as f:
        json.dump(log, f, indent=1)
    os.replace(OUT + ".tmp", OUT)


if __name__ == "__main__":
    raise SystemExit(main())

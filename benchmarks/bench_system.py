"""Mesh-of-chips benchmark: committed multi-chip performance golden.

Compiles the full-size ``transformer`` workload onto 1/2/4/8-chip
meshes through :mod:`repro.system` at trace fidelity and records, per
mesh size and parallelism mode, the end-to-end cycles, the inter-chip
communication cycles, and the delivered throughput (samples/s and
tok/s at the workload's sequence length).

The single-chip row runs the classic (non-system) path — the full
transformer's resident weights exceed one chip's gmem, so the system
partitioner rightly refuses it at 1 chip; the mesh rows are exactly
the capacity wall the scale-out layer exists to clear.

Every number derives from deterministic cycle counts, so ``--smoke``
fails on ANY drift vs the committed ``BENCH_system.json`` (regenerate
with ``--update-golden`` and commit the diff when a cost-model change
is intentional).

    PYTHONPATH=src python -m benchmarks.bench_system [--smoke]
        [--update-golden] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_PATH = os.path.join(_ROOT, "BENCH_system.json")

MODEL = "transformer"
SEQ = 128                    # transformer_lm default — tokens/sample
FIDELITY = "trace"
LINK = "pcb"
MESHES = (1, 2, 4, 8)

_GATED = ("cycles", "comm_cycles", "throughput_sps", "tok_s")


def _round(x: float) -> float:
    return round(float(x), 9)


def bench_doc() -> Dict:
    from repro import flow
    from repro.core.arch import default_chip
    from repro.flow import CompileOptions
    from repro.system import SystemConfig

    chip = default_chip()
    meshes: Dict[str, Dict] = {}
    for n in MESHES:
        entry: Dict[str, Dict] = {}
        modes = ("single",) if n == 1 else ("pipeline", "tensor")
        for mode in modes:
            system = None if mode == "single" else SystemConfig.mesh(
                n, link=LINK, parallel=mode)
            art = flow.compile(MODEL, chip, CompileOptions(
                fidelity=FIDELITY, system=system))
            rep = art.evaluate()
            entry[mode] = {
                "cycles": _round(rep.cycles),
                "comm_cycles": _round(getattr(rep, "comm_cycles", 0)),
                "throughput_sps": _round(rep.throughput_sps),
                "tok_s": _round(rep.throughput_sps * SEQ),
            }
        meshes[str(n)] = entry
    return {
        "schema": 1,
        "model": MODEL,
        "seq": SEQ,
        "fidelity": FIDELITY,
        "link": LINK,
        "chip": "default",
        "meshes": meshes,
    }


def report(doc: Dict) -> str:
    out = [f"== system bench ({doc['model']}, fidelity="
           f"{doc['fidelity']}, link={doc['link']}) =="]
    for n, entry in doc["meshes"].items():
        for mode, m in entry.items():
            out.append(
                f"chips={n:>2} {mode:<8s} cycles={m['cycles']:>12.0f} "
                f"comm={m['comm_cycles']:>11.0f} "
                f"tok/s={m['tok_s']:>10.0f}")
    return "\n".join(out)


def smoke_drift(doc: Dict, golden: Dict) -> List[str]:
    """Failures vs the committed golden (empty = clean)."""
    drift: List[str] = []
    for n in sorted(set(doc["meshes"]) | set(golden["meshes"]), key=int):
        dm = doc["meshes"].get(n)
        gm = golden["meshes"].get(n)
        if dm is None or gm is None:
            drift.append(f"mesh {n}: "
                         f"{'missing' if dm is None else 'new'} "
                         f"vs golden")
            continue
        for mode in sorted(set(dm) | set(gm)):
            a, b = dm.get(mode), gm.get(mode)
            if a is None or b is None:
                drift.append(f"mesh {n}.{mode}: "
                             f"{'missing' if a is None else 'new'}")
                continue
            for k in _GATED:
                if _round(a[k]) != _round(b[k]):
                    drift.append(f"mesh {n}.{mode}.{k}: "
                                 f"{b[k]} -> {a[k]}")
    # structural invariants, independent of the golden numbers
    m = doc["meshes"]
    if m["4"]["tensor"]["comm_cycles"] <= m["2"]["tensor"]["comm_cycles"]:
        drift.append("tensor comm no longer grows with chip count")
    if m["2"]["pipeline"]["throughput_sps"] <= \
            m["1"]["single"]["throughput_sps"]:
        drift.append("2-chip pipeline no longer beats one chip")
    return drift


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="gate against the committed golden (CI job)")
    ap.add_argument("--update-golden", action="store_true",
                    help=f"rewrite {GOLDEN_PATH}")
    ap.add_argument("--json", default="results/bench_system.json",
                    help="also write the measured doc here ('' to skip)")
    args = ap.parse_args(argv)

    doc = bench_doc()
    print(report(doc))
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")
    if args.update_golden:
        with open(GOLDEN_PATH, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"golden updated: {GOLDEN_PATH}")
        return 0
    if args.smoke:
        try:
            with open(GOLDEN_PATH) as f:
                golden = json.load(f)
        except FileNotFoundError:
            print(f"golden {GOLDEN_PATH} missing "
                  f"(generate with --update-golden)")
            return 1
        drift = smoke_drift(doc, golden)
        if drift:
            print("SYSTEM BENCH DRIFT vs committed golden:")
            for d in drift:
                print(f"  {d}")
            print("if the cost-model change is intentional, regenerate "
                  "with `python -m benchmarks.bench_system "
                  "--update-golden` and commit the diff")
            return 1
        g4 = golden["meshes"]["4"]
        print("golden: clean (committed 4-chip pipeline "
              f"tok/s={g4['pipeline']['tok_s']:.0f}, "
              f"tensor tok/s={g4['tensor']['tok_s']:.0f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Generate EXPERIMENTS.md from results/*.json.

    PYTHONPATH=src python -m benchmarks.make_experiments
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from repro.configs import ARCHS, STANDARD_SHAPES

HW_NOTE = ("TPU v5e-class chip constants: 197 TFLOP/s bf16, 819 GB/s "
           "HBM, 4 x 50 GB/s ICI links, 16 GiB HBM.")


def _load(name: str):
    path = f"results/{name}.json"
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def fig5_section(rows) -> List[str]:
    out = ["## §Fig5 — compilation strategies (cycle-accurate simulator)",
           "",
           "Speed normalized to the generic baseline (higher = faster); "
           "energy relative to generic (lower = better). 112x112 inputs, "
           "batch 4, Tab. I default architecture.", "",
           "| model | strategy | speedup | energy (rel) | stages |",
           "|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['model']} | {r['strategy']} | "
                   f"{r['speed_norm']:.2f}x | {r['energy_norm']:.2f} | "
                   f"{r['n_stages']} |")
    dp = [r for r in rows if r["strategy"] == "dp"]
    mlc = {r["model"]: r for r in rows if r["strategy"] == "cim-mlc"}
    best = max(dp, key=lambda r: r["speed_norm"])
    beste = min(dp, key=lambda r: r["energy_norm"])
    vs_mlc = max(dp, key=lambda r: mlc[r["model"]]["cycles"]
                 / r["cycles"])
    out += ["",
            f"**Paper claims**: up to 2.8x speedup / 61.7% energy "
            f"reduction vs baselines, largest wins on compact models.  "
            f"**Reproduced**: up to {best['speed_norm']:.2f}x vs generic "
            f"({best['model']}), "
            f"{mlc[vs_mlc['model']]['cycles'] / vs_mlc['cycles']:.2f}x vs "
            f"CIM-MLC-style ({vs_mlc['model']}), "
            f"{100 * (1 - beste['energy_norm']):.1f}% energy reduction "
            f"({beste['model']}).  The compact models (MobileNetV2 / "
            f"EfficientNetB0) show the largest DP-vs-opportunistic gaps, "
            f"matching the paper's analysis; absolute ratios differ "
            f"(different macro timings, re-normalized energy tables — "
            f"DESIGN.md §2).", ""]
    return out


def _dyn_shares(r):
    """Dynamic-energy shares (the paper's Fig. 6 breakdown excludes the
    leakage floor; at batch-4 utilization our static term would swamp
    the chart — it is reported separately)."""
    move = (r["energy_noc_frac"] + r["energy_gmem_frac"]
            + r["energy_weight_load_frac"] + r["energy_lmem_frac"])
    comp = r["energy_compute_frac"]
    dyn = move + comp
    return (comp / dyn if dyn else 0.0), (move / dyn if dyn else 0.0)


def fig6_section(rows) -> List[str]:
    out = ["## §Fig6 — MG size x NoC bandwidth (generic mapping)",
           "",
           "Dynamic-energy breakdown (compute vs data movement = "
           "NoC + gmem + lmem + weight load); the idle-core static floor "
           "is listed separately (batch-4 streaming leaves most of the "
           "700-TOPS array idle — the latency wins in Fig5 reclaim it).",
           "",
           "| model | MG | flit B | thpt (sps@1GHz) | compute %dyn | "
           "data-movement %dyn | static % of total |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        comp, move = _dyn_shares(r)
        out.append(
            f"| {r['model']} | {r['mg']} | {r['flit']} | "
            f"{r['throughput_sps']:.1f} | "
            f"{100 * comp:.0f} | {100 * move:.0f} | "
            f"{100 * r['energy_static_frac']:.0f} |")
    res = [r for r in rows if r["model"] == "resnet18"]
    eff = [r for r in rows if r["model"] == "efficientnetb0"]
    r_gain = (max(x["throughput_sps"] for x in res)
              / min(x["throughput_sps"] for x in res))
    e_gain = (max(x["throughput_sps"] for x in eff)
              / min(x["throughput_sps"] for x in eff))
    eff_move = max(_dyn_shares(x)[1] for x in eff)
    res_move = max(_dyn_shares(x)[1] for x in res)
    out += ["",
            f"**Trends vs paper**: ResNet18 scales {r_gain:.2f}x across "
            f"the sweep with compute-dominated dynamic energy "
            f"(data movement <= {100 * res_move:.0f}%; paper: compute "
            f"remains dominant, +39.6% from 2x flit), EfficientNetB0 "
            f"only {e_gain:.2f}x with data movement up to "
            f"{100 * eff_move:.0f}% of dynamic energy (paper: up to "
            f"55.4%) — the compact-model data-movement wall the paper "
            f"highlights.", ""]
    return out


def fig7_section(rows) -> List[str]:
    out = ["## §Fig7 — SW/HW co-design space", "",
           "Analytic cost model (the DSE front-end; ~10x optimistic on "
           "absolute throughput vs the simulator but order-preserving — "
           "`examples/dse_sweep.py` validates the Pareto point with the "
           "cycle-accurate simulator).", "",
           "| model | strategy | MG | flit | thpt (sps) |",
           "|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['model']} | {r['strategy']} | {r['mg']} | "
                   f"{r['flit']} | {r['throughput_sps']:.1f} |")
    for model in sorted({r["model"] for r in rows}):
        sub = [r for r in rows if r["model"] == model]
        dp4 = max(r["throughput_sps"] for r in sub
                  if r["strategy"] == "dp" and r["mg"] == 4)
        g16 = max(r["throughput_sps"] for r in sub
                  if r["strategy"] == "generic" and r["mg"] == 16)
        out.append("")
        out.append(f"**{model}**: dp@MG4 = {dp4:.1f} sps vs "
                   f"generic@MG16 = {g16:.1f} sps — compilation "
                   f"{'inverts' if dp4 > g16 else 'narrows'} the 4x "
                   f"hardware gap (the paper's Fig. 7 argument).")
    out.append("")
    return out


def dryrun_section(data) -> List[str]:
    out = ["## §Dry-run — every (arch x shape) x {16x16, 2x16x16}", "",
           "`python -m repro.launch.dryrun --all --both-meshes` — "
           "`.lower().compile()` for train_step (train_4k), prefill "
           "(prefill_32k) and serve/decode steps (decode_32k, long_500k) "
           "with full in/out shardings. " + HW_NOTE, "",
           "| arch | shape | mesh | status | GiB/chip | fits 16G | "
           "head shard | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    for key in sorted(data):
        r = data[key]
        mesh = "2x16x16" if key.endswith("2pod") else "16x16"
        if r["status"] == "skipped":
            n_skip += 1
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                       f"skipped¹ | - | - | - | - |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                       f"ERROR | - | - | - | - |")
            continue
        n_ok += 1
        m = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
            f"{m.get('live_gib', 0):.1f} | "
            f"{'yes' if m.get('fits_16g') else 'no²'} | "
            f"{r.get('head_sharding', '-')} | {r.get('compile_s', '-')} |")
    out += ["",
            f"{n_ok} cells compile, {n_skip} skipped.  "
            "¹ long_500k on full-quadratic-attention archs "
            "(DESIGN.md §3).  ² cells exceeding 16 GiB/chip quantify the "
            "capacity wall the planner (Alg. 1 at pod scale) addresses "
            "with pipeline stages + ZeRO/offload — recorded, not hidden; "
            "the 671B/398B configs require >256 chips or optimizer-state "
            "sharding beyond this mesh (see DESIGN.md §4).", ""]
    return out


def roofline_section(data) -> List[str]:
    out = ["## §Roofline — per-chip terms (single-pod 16x16)", "",
           "Methodology: XLA `cost_analysis()` counts `while`-loop bodies "
           "once (verified: scan flops are trip-count-invariant), so "
           "step totals are reconstructed from fully-unrolled depth-1/-2 "
           "probe compiles, `X(1) + (n_blocks-1)(X(2)-X(1))`: a "
           "naive-attention probe gives exact FLOPs (flash reorders, "
           "doesn't add, dot FLOPs); a flash-path probe gives bytes + "
           "collectives, with flash K/V streaming added analytically "
           "(`analysis.flash_addons`); `ragged_dot` is probed as a "
           "balanced batched matmul (XLA prices it dense-over-groups). "
           "Collective link-bytes model: all-reduce 2R, others R, over 4 "
           "ICI links. 'bytes accessed' from the CPU backend under-fuses "
           "vs TPU, so memory terms are conservative upper bounds; "
           "relative (before/after) comparisons remain valid. "
           + HW_NOTE, "",
           "| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | note |",
           "|---|---|---|---|---|---|---|---|"]
    for key in sorted(data):
        if not key.endswith("|1pod"):
            continue
        r = data[key]
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        uf = r.get("useful_flops_frac")
        note = r.get("note", "")
        if not note and r["kind"] in ("decode", "long_decode"):
            note = "attention-over-cache flops excluded from MODEL_FLOPS"
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"{rf['dominant']} | "
            f"{uf:.2f} | {note[:70]} |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"{rf['dominant']} | - | {note[:70]} |")
    out += ["",
            "Reading the table: train cells sit at MODEL/HLO ≈ 0.71 — "
            "exactly the 6ND/8.4ND ratio full rematerialization implies "
            "(the 'remat waste' the ratio is designed to catch).  Decode "
            "cells show tiny ratios because the 2ND convention excludes "
            "attention over the 32k cache, which dominates their real "
            "compute.  head_dim-fallback attention (phi3, dscoder, "
            "danube, whisper, llava) pays an S²-scores psum, visible as "
            "collective-heavy train/prefill cells — attacked in §Perf.",
            ""]
    return out


def perf_section(log) -> List[str]:
    out = ["## §Perf — hypothesis -> change -> measure -> validate", "",
           "Three cells hillclimbed (most collective-bound / worst "
           "capacity / bandwidth-bound decode, per the baseline table); "
           "knobs in `repro/launch/tuning.py`; every row re-runs the "
           "full corrected-probe pipeline.  The paper-faithful baseline "
           "is recorded first, beyond-paper optimizations after it.", ""]
    cells = []
    for e in log:
        if e["cell"] not in cells:
            cells.append(e["cell"])
    for cell in cells:
        entries = [e for e in log if e["cell"] == cell]
        base = next((e for e in entries if e["config"] == "baseline"),
                    None)
        out.append(f"### {cell}")
        out.append("")
        out.append("| config | compute s | memory s | collective s | "
                   "dominant | GiB/chip | verdict vs hypothesis |")
        out.append("|---|---|---|---|---|---|---|")
        bload = None
        for e in entries:
            if "error" in e:
                out.append(f"| {e['config']} | - | - | - | - | - | "
                           f"ERROR: {e['error'][:60]} |")
                continue
            r = e["result"]["roofline"]
            mem = e["result"].get("memory") or {}
            gib = mem.get("live_gib")
            if e["config"] == "baseline":
                bload = r
                verdict = "baseline"
            else:
                verdict = _verdict(bload, r)
            out.append(
                f"| {e['config']} | {r['compute_s']:.3g} | "
                f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
                f"{r['dominant']} | "
                f"{gib:.1f} | {verdict} |" if gib is not None else
                f"| {e['config']} | {r['compute_s']:.3g} | "
                f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
                f"{r['dominant']} | - | {verdict} |")
        out.append("")
        for e in entries:
            if e["config"] != "baseline":
                out.append(f"* **{e['config']}** — {e['hypothesis']}")
        out.append("")
    # summary: roofline fractions, paper-faithful vs beyond-paper
    out += ["### §Perf summary — paper-faithful baseline vs optimized",
            "",
            "| cell | baseline bound s | best bound s | speedup | "
            "baseline compute-roofline | optimized compute-roofline |",
            "|---|---|---|---|---|---|"]
    for cell in cells:
        entries = [e for e in log if e["cell"] == cell
                   and "result" in e]
        base = next(e for e in entries if e["config"] == "baseline")
        br = base["result"]["roofline"]
        b_bound = max(br["compute_s"], br["memory_s"],
                      br["collective_s"])
        best = min(entries, key=lambda e: max(
            e["result"]["roofline"]["compute_s"],
            e["result"]["roofline"]["memory_s"],
            e["result"]["roofline"]["collective_s"]))
        orr = best["result"]["roofline"]
        o_bound = max(orr["compute_s"], orr["memory_s"],
                      orr["collective_s"])
        out.append(
            f"| {cell} | {b_bound:.3g} | {o_bound:.3g} "
            f"({best['config']}) | {b_bound / o_bound:.2f}x | "
            f"{100 * br['compute_s'] / b_bound:.1f}% | "
            f"{100 * orr['compute_s'] / o_bound:.1f}% |")
    out += ["",
            "For deepseek-v3-671b the binding constraint is **capacity**, "
            "not a time term: the paper-faithful baseline needs 1011.8 "
            "GiB/chip (6.3x over HBM — it cannot run at all); "
            "`fsdp_params` cuts it 3.3x to 302 GiB for a 13% traffic "
            "increase, the planner's predicted ZeRO trade.  Remaining "
            "capacity needs the planner's pipeline stages (PP=11 per "
            "`core/planner`) — the Alg. 1 capacity wall, reproduced at "
            "pod scale.",
            "",
            "Compute-roofline fraction = compute term / binding term "
            "(how close the cell sits to the 197-TFLOP/s ceiling). "
            "Memory terms are conservative upper bounds (CPU-backend "
            "fusion < TPU fusion; see methodology), so the optimized "
            "fractions are lower bounds on real-TPU attainment.  Beyond "
            "the three hillclimbed cells, `attn_seq_parallel` applies "
            "identically to every head_dim-fallback arch (phi3, phi4, "
            "danube, whisper, llava — all collective-bound in the "
            "baseline table), `int8_kv_cache` to every decode cell, and "
            "`fsdp_params` to every capacity-infeasible train cell; the "
            "knobs are production config options, not one-off patches.",
            ""]
    return out


def _verdict(base, r) -> str:
    if base is None:
        return "-"
    before = max(base["compute_s"], base["memory_s"],
                 base["collective_s"])
    after = max(r["compute_s"], r["memory_s"], r["collective_s"])
    if after < before * 0.95:
        return f"confirmed: bound {before:.3g}->{after:.3g}s " \
               f"({before / after:.1f}x)"
    if after > before * 1.05:
        return f"refuted: bound {before:.3g}->{after:.3g}s (worse)"
    return "neutral (<5%)"


def main() -> int:
    parts: List[str] = [
        "# EXPERIMENTS", "",
        "Reproduction + at-scale evaluation of CIMFlow (cs.AR 2025). "
        "All numbers regenerate via `python -m benchmarks.run` and "
        "`python -m repro.launch.dryrun --all --both-meshes`; this file "
        "via `python -m benchmarks.make_experiments`.", "",
    ]
    fig5 = _load("bench_fig5")
    if fig5:
        parts += fig5_section(fig5)
    fig6 = _load("bench_fig6")
    if fig6:
        parts += fig6_section(fig6)
    fig7 = _load("bench_fig7")
    if fig7:
        parts += fig7_section(fig7)
    dr = _load("dryrun")
    if dr:
        parts += dryrun_section(dr)
        parts += roofline_section(dr)
    perf = _load("perf_log")
    if perf:
        parts += perf_section(perf)
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts) + "\n")
    print(f"wrote EXPERIMENTS.md ({len(parts)} blocks)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
